//! Paged-KV parity under continuous batching: sequences admitted at
//! **different steps** with **heterogeneous prompt lengths**, decoding
//! through the shared block arena, must produce token streams bit-identical
//! to generating each sequence alone on the contiguous reference cache — for
//! every registered quant method, both decode-kernel families, and pool
//! widths 1/2/4. A deliberately tiny block size (4 positions) forces every
//! sequence across multiple block-table boundaries.
//!
//! The prefix-sharing suite at the bottom drives the real [`ServerHandle`]
//! scheduler: staggered admissions whose prompts share a long prefix must
//! alias resident blocks (block-boundary and mid-block divergence, plus the
//! exact-full-match prompt that forces admission's copy-on-write reserve) and
//! still stream bit-identically to solo contiguous decode — including under a
//! budget tight enough to evict live holders of shared blocks.

use std::collections::VecDeque;
use std::sync::Arc;

use qtip::coordinator::{quantize_model_qtip, GenRequest, ServerConfig, ServerHandle};
use qtip::hessian::collect_hessians;
use qtip::model::{
    DecodeScratch, KvArena, KvCache, KvLayout, KvSeq, ModelConfig, Transformer, WeightStore,
};
use qtip::quant::{registry, KernelKind, QtipConfig};
use qtip::util::threadpool::ExecPool;

const WIDTHS: [usize; 3] = [1, 2, 4];
const BLOCK: usize = 4;

/// Every registered method as a (code name, V) quantizer config — iterating
/// the registry keeps this sweep complete as methods are added.
fn codes() -> Vec<(&'static str, u32)> {
    registry::all().iter().map(|m| (m.name(), m.preferred_v())).collect()
}

fn quantized_tiny(code: &str, v: u32) -> Transformer {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 64;
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 21));
    let seqs = vec![(0..48u16).collect::<Vec<_>>(), (60..108u16).collect::<Vec<_>>()];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v, tx: 8, ty: 8, code: code.into(), seed: 5 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    model
}

/// One simulated request: a prompt, a generation budget, and the round at
/// which the scheduler admits it.
struct Job {
    prompt: Vec<u16>,
    max_new: usize,
    join_round: usize,
}

fn jobs() -> Vec<Job> {
    vec![
        Job { prompt: vec![10, 200, 37, 99, 5, 7, 7], max_new: 9, join_round: 0 },
        Job { prompt: vec![42], max_new: 12, join_round: 2 },
        Job { prompt: (0..13).map(|i| (i * 17) as u16).collect(), max_new: 5, join_round: 3 },
        Job { prompt: vec![250, 1, 2], max_new: 8, join_round: 7 },
    ]
}

/// Reference: each job generated alone on a contiguous cache (greedy).
fn solo_streams(model: &Transformer, pool: &ExecPool) -> Vec<Vec<u16>> {
    let mut scratch = DecodeScratch::new(&model.cfg);
    let mut out = Vec::new();
    for job in jobs() {
        let mut cache = KvCache::new(&model.cfg);
        let mut logits: Vec<f32> = Vec::new();
        for &t in &job.prompt {
            logits = model.decode_step_with(&mut cache, t, &mut scratch, pool).to_vec();
        }
        let mut tokens = Vec::new();
        let mut rng = qtip::util::rng::Rng::new(1);
        let mut next = Transformer::sample(&logits, 0.0, 1, &mut rng);
        loop {
            tokens.push(next);
            if tokens.len() >= job.max_new {
                break;
            }
            let l = model.decode_step_with(&mut cache, next, &mut scratch, pool);
            next = Transformer::sample(l, 0.0, 1, &mut rng);
        }
        out.push(tokens);
    }
    out
}

/// A sequence mid-flight in the simulated continuous batcher.
struct Live {
    job_idx: usize,
    seq: KvSeq,
    pending: VecDeque<u16>,
    next: Option<u16>,
    generated: Vec<u16>,
}

/// Continuous batching over the paged arena: jobs join at their round, share
/// fused rounds with whatever else is live, and leave when done.
fn paged_streams(model: &Transformer, pool: &ExecPool) -> Vec<Vec<u16>> {
    let all = jobs();
    let n_blocks = all.len() * model.cfg.max_seq.div_ceil(BLOCK);
    let mut arena = KvArena::new(&model.cfg, BLOCK, n_blocks);
    let mut scratch = DecodeScratch::new(&model.cfg);
    let mut live: Vec<Live> = Vec::new();
    let mut done: Vec<Option<Vec<u16>>> = (0..all.len()).map(|_| None).collect();
    let mut rng = qtip::util::rng::Rng::new(1);
    let mut round = 0usize;
    while done.iter().any(|d| d.is_none()) {
        for (ji, job) in all.iter().enumerate() {
            if job.join_round == round {
                live.push(Live {
                    job_idx: ji,
                    seq: KvSeq::new(),
                    pending: job.prompt.iter().copied().collect(),
                    next: None,
                    generated: Vec::new(),
                });
            }
        }
        let mut tokens: Vec<u16> = Vec::new();
        let mut stepping: Vec<usize> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        for (i, l) in live.iter_mut().enumerate() {
            if let Some(t) = l.pending.pop_front() {
                tokens.push(t);
                stepping.push(i);
                continue;
            }
            let t = l.next.expect("decoding sequence holds a token");
            l.generated.push(t);
            if l.generated.len() >= all[l.job_idx].max_new {
                finished.push(i);
                continue;
            }
            tokens.push(t);
            stepping.push(i);
        }
        if !tokens.is_empty() {
            let mut refs: Vec<&mut KvSeq> = Vec::new();
            {
                let mut want = stepping.iter().peekable();
                for (i, l) in live.iter_mut().enumerate() {
                    if want.peek() == Some(&&i) {
                        want.next();
                        let need = l.seq.len + 1;
                        assert!(arena.ensure(&mut l.seq, need), "arena sized for all jobs");
                        refs.push(&mut l.seq);
                    }
                }
            }
            let logits =
                model.decode_step_batch_paged(&mut arena, &mut refs, &tokens, &mut scratch, pool);
            for (j, &i) in stepping.iter().enumerate() {
                let l = &mut live[i];
                if !l.pending.is_empty() {
                    continue;
                }
                l.next = Some(Transformer::sample(logits.row(j), 0.0, 1, &mut rng));
            }
        }
        for i in finished.drain(..).rev() {
            let mut l = live.remove(i);
            arena.release(&mut l.seq);
            done[l.job_idx] = Some(l.generated);
        }
        // Round boundary: the live block tables and the free list must form
        // an exact partition of the pool (mirrors the serve loop's debug
        // check, but unconditional here).
        arena.assert_partition(live.iter().map(|l| &l.seq));
        round += 1;
        assert!(round < 10_000, "simulated batcher failed to converge");
    }
    assert_eq!(arena.blocks_in_use(), 0, "every finished sequence must release its blocks");
    done.into_iter().map(|d| d.unwrap()).collect()
}

#[test]
fn continuous_paged_batching_matches_solo_for_all_codes_kernels_widths() {
    for (code, v) in codes() {
        let mut model = quantized_tiny(code, v);
        for kernel in [KernelKind::Scalar, KernelKind::Lanes] {
            model.set_decode_kernel(kernel);
            let reference = solo_streams(&model, &ExecPool::sequential());
            for width in WIDTHS {
                let pool = ExecPool::new(width);
                let got = paged_streams(&model, &pool);
                assert_eq!(
                    got,
                    reference,
                    "{code} kernel={} width={width}: paged continuous batching diverged \
                     from solo contiguous decode",
                    kernel.name()
                );
            }
        }
    }
}

#[test]
fn paged_single_round_logits_match_contiguous_for_all_codes() {
    // Direct logits-level parity (not just argmax tokens): one fused batch
    // round over the arena vs the contiguous caches, per registered method.
    for (code, v) in codes() {
        let model = quantized_tiny(code, v);
        let pool = ExecPool::new(2);
        let mut scratch = DecodeScratch::new(&model.cfg);
        let streams: [&[u16]; 3] = [&[9, 8, 7, 6], &[1, 2], &[100]];

        let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&model.cfg)).collect();
        let mut arena = KvArena::new(&model.cfg, BLOCK, 3 * model.cfg.max_seq.div_ceil(BLOCK));
        let mut seqs: Vec<KvSeq> = (0..3).map(|_| KvSeq::new()).collect();
        let max_len = streams.iter().map(|s| s.len()).max().unwrap();
        for pos in 0..max_len {
            let (mut tokens, mut idxs) = (Vec::new(), Vec::new());
            for (i, s) in streams.iter().enumerate() {
                if pos < s.len() {
                    tokens.push(s[pos]);
                    idxs.push(i);
                }
            }
            let mut want: Vec<Vec<f32>> = Vec::new();
            {
                let mut refs: Vec<&mut KvCache> = Vec::new();
                for (i, c) in caches.iter_mut().enumerate() {
                    if idxs.contains(&i) {
                        refs.push(c);
                    }
                }
                let logits = model.decode_step_batch_with(&mut refs, &tokens, &mut scratch, &pool);
                for j in 0..tokens.len() {
                    want.push(logits.row(j).to_vec());
                }
            }
            let mut refs: Vec<&mut KvSeq> = Vec::new();
            for (i, s) in seqs.iter_mut().enumerate() {
                if idxs.contains(&i) {
                    let need = s.len + 1;
                    assert!(arena.ensure(&mut *s, need));
                    refs.push(s);
                }
            }
            let logits = model
                .decode_step_batch_paged(&mut arena, &mut refs, &tokens, &mut scratch, &pool);
            for j in 0..tokens.len() {
                assert_eq!(
                    logits.row(j),
                    &want[j][..],
                    "{code} pos={pos} seq={j}: paged round diverged from contiguous"
                );
            }
            // After every fused round the arena partition must be exact over
            // the full table set (including sequences idle this round).
            arena.assert_partition(seqs.iter());
        }
    }
}

// ---------------------------------------------------------------------------
// Prefix-sharing parity: the real server scheduler with the hashed-block
// prefix index enabled.
// ---------------------------------------------------------------------------

/// 12 bytes = exactly 3 whole blocks at the 4-position test block size, so a
/// prompt that is the prefix alone fully matches the index (the CoW case).
const SHARED_PREFIX: &str = "SYSTEM: do x";

fn req(id: u64, prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.to_string(),
        max_new_tokens: max_new,
        temperature: 0.0,
        top_k: 1,
        seed: id,
        model: String::new(),
        deadline_ms: 0,
    }
}

/// Reference streams: each request served alone on the contiguous scheduler
/// (sequential submission, batch width 1 — no sharing, no paging).
fn solo_reference(model: &Arc<Transformer>, threads: usize, reqs: &[GenRequest]) -> Vec<Vec<u16>> {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 1,
            threads,
            kv_layout: KvLayout::Contig,
            ..Default::default()
        },
    );
    let out = reqs
        .iter()
        .map(|r| {
            let resp = server.submit(r.clone()).recv().expect("solo request served");
            assert!(resp.error.is_none(), "solo request rejected: {:?}", resp.error);
            resp.tokens
        })
        .collect();
    server.shutdown();
    out
}

/// Staggered admission with every divergence shape: a seed sequence runs to
/// completion (registering its prefix blocks in the index), then three
/// sharers arrive at once — one diverging exactly on a block boundary, one
/// mid-block, and one whose prompt *is* the shared prefix (full match ⇒ the
/// admission CoW reserve and a real copy-on-write on its first decode round).
/// All streams must be bit-identical to solo contiguous decode for every
/// registered method and pool widths 1/2.
#[test]
fn prefix_shared_admission_is_bit_identical_for_all_codes() {
    assert_eq!(SHARED_PREFIX.len(), 3 * BLOCK, "prefix must cover whole blocks");
    let jobs = vec![
        req(0, &format!("{SHARED_PREFIX}A1"), 6),
        // Divergence at position 12 — the first block boundary past the prefix.
        req(1, &format!("{SHARED_PREFIX}B2"), 6),
        // Divergence at position 10 — inside block 2, so only 2 blocks alias.
        req(2, &format!("{}zzzz", &SHARED_PREFIX[..10]), 6),
        // The prefix alone: all 3 blocks alias, the cursor re-enters the last
        // shared block, and the first decode round must copy-on-write it.
        req(3, SHARED_PREFIX, 6),
    ];
    for (code, v) in codes() {
        let model = Arc::new(quantized_tiny(code, v));
        for threads in [1usize, 2] {
            let reference = solo_reference(&model, threads, &jobs);
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig {
                    max_batch: 4,
                    threads,
                    kv_layout: KvLayout::Paged,
                    kv_block: BLOCK,
                    prefix_share: true,
                    ..Default::default()
                },
            );
            // Seed first, alone: its completed blocks stay index-resident.
            let r0 = server.submit(jobs[0].clone()).recv().expect("seed served");
            assert!(r0.error.is_none(), "{code}: {:?}", r0.error);
            let rxs: Vec<_> = jobs[1..].iter().map(|j| server.submit(j.clone())).collect();
            let mut got = vec![r0.tokens];
            for rx in rxs {
                let r = rx.recv().expect("sharer served");
                assert!(r.error.is_none(), "{code}: {:?}", r.error);
                got.push(r.tokens);
            }
            let stats = server.shutdown();
            assert_eq!(
                got, reference,
                "{code} threads={threads}: prefix-shared decode diverged from solo contiguous"
            );
            // The three sharers hit (3 + 2 + 3 aliased blocks); the full-match
            // prompt privatizes its last aliased block exactly once.
            assert_eq!(stats.prefix_hits, 3, "{code}: every sharer must hit the index");
            assert_eq!(stats.blocks_shared, 8, "{code}: 3+2+3 blocks must alias");
            assert_eq!(stats.cow_copies, 1, "{code}: the full-match prompt must CoW once");
            assert_eq!(stats.completed, jobs.len());
        }
    }
}

/// Tight budget: 16 four-position blocks is ~2.5 sequences' worth for six
/// same-prefix requests at batch width 4, so the scheduler must reclaim
/// index-held blocks, stall behind finishers, and evict live holders of
/// shared blocks — and every preempted request's deterministic replay (now
/// aliasing the prefix its first run registered) must still be bit-identical
/// to solo contiguous decode.
#[test]
fn prefix_sharing_parity_survives_eviction_under_tight_budget() {
    let jobs: Vec<GenRequest> =
        (0..6).map(|i| req(i, &format!("{SHARED_PREFIX}#{i}"), 6)).collect();
    let (code, v) = codes()[1];
    let model = Arc::new(quantized_tiny(code, v));
    let block_bytes = KvArena::block_bytes(&model.cfg, BLOCK);
    for threads in [1usize, 2] {
        let reference = solo_reference(&model, threads, &jobs);
        let server = ServerHandle::spawn(
            model.clone(),
            ServerConfig {
                max_batch: 4,
                threads,
                kv_budget_bytes: 16 * block_bytes,
                kv_layout: KvLayout::Paged,
                kv_block: BLOCK,
                prefix_share: true,
                ..Default::default()
            },
        );
        let rxs: Vec<_> = jobs.iter().map(|j| server.submit(j.clone())).collect();
        let got: Vec<Vec<u16>> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv().expect("request served under pressure");
                assert!(r.error.is_none(), "{code}: {:?}", r.error);
                r.tokens
            })
            .collect();
        let stats = server.shutdown();
        assert_eq!(
            got, reference,
            "{code} threads={threads}: eviction/reclaim under sharing broke bit-identity"
        );
        assert_eq!(stats.completed, jobs.len());
        assert_eq!(stats.kv_blocks_total, 16, "budget must size the arena to 16 blocks");
    }
}

/// Sharing off is a pure A/B switch: the same staggered workload with
/// `prefix_share: false` must produce the same streams with zero hits.
#[test]
fn prefix_sharing_off_matches_and_reports_no_hits() {
    let jobs = vec![
        req(0, &format!("{SHARED_PREFIX}A1"), 5),
        req(1, &format!("{SHARED_PREFIX}B2"), 5),
    ];
    let (code, v) = codes()[0];
    let model = Arc::new(quantized_tiny(code, v));
    let reference = solo_reference(&model, 1, &jobs);
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 2,
            threads: 1,
            kv_layout: KvLayout::Paged,
            kv_block: BLOCK,
            prefix_share: false,
            ..Default::default()
        },
    );
    let r0 = server.submit(jobs[0].clone()).recv().expect("first served");
    let r1 = server.submit(jobs[1].clone()).recv().expect("second served");
    assert!(r0.error.is_none() && r1.error.is_none());
    let stats = server.shutdown();
    assert_eq!(vec![r0.tokens, r1.tokens], reference);
    assert_eq!(stats.prefix_hits, 0, "sharing disabled must never alias");
    assert_eq!(stats.blocks_shared, 0);
    assert_eq!(stats.cow_copies, 0);
}
