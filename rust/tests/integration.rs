//! Cross-module integration: golden cross-language code vectors, full
//! quantize→reconstruct→matvec consistency, corpus→hessian→LDLQ chain.

use std::path::Path;

use qtip::codes::{build_code, Code};
use qtip::hessian::collect_hessians;
use qtip::model::{ModelConfig, Transformer, WeightStore};
use qtip::quant::{quantize_matrix_qtip, QtipConfig};
use qtip::util::json::Json;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;

fn artifacts() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The cross-language contract: python-generated golden decode values must match
/// the Rust decoders exactly (DESIGN.md §7).
#[test]
fn golden_codes_match_python() {
    let path = artifacts().join("golden_codes.json");
    if !path.exists() {
        eprintln!("skipping golden test: run `make artifacts`");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let onemad = build_code("1mad", 16, 1, 0);
    let threeinst = build_code("3inst", 16, 1, 0);
    let g1 = j.get("1mad").unwrap().as_arr().unwrap();
    let g3 = j.get("3inst").unwrap().as_arr().unwrap();
    assert_eq!(g1.len(), 1024);
    let mut out = [0.0f32];
    for s in 0..1024u32 {
        onemad.decode(s, &mut out);
        let want = g1[s as usize].as_f64().unwrap();
        assert!(
            (out[0] as f64 - want).abs() < 1e-6,
            "1mad state {s}: rust {} python {want}",
            out[0]
        );
        threeinst.decode(s, &mut out);
        let want = g3[s as usize].as_f64().unwrap();
        assert!(
            (out[0] as f64 - want).abs() < 1e-6,
            "3inst state {s}: rust {} python {want}",
            out[0]
        );
    }
}

/// HYB LUT artifact loads and produces a working shared-LUT code.
#[test]
fn hyb_lut_contract() {
    let dir = artifacts();
    if !dir.join("hyb_lut_q9.json").exists() {
        return;
    }
    let reg = qtip::runtime::Registry::open(&dir).unwrap();
    let lut = reg.load_hyb_lut(9).unwrap();
    let code = qtip::codes::HybridCode::from_lut(16, 2, 9, lut);
    let values = code.materialize();
    assert_eq!(values.len(), 65536 * 2);
    assert!(values.iter().all(|v| v.is_finite()));
}

/// Whole-chain determinism: same seed → bit-identical quantized artifact.
#[test]
fn quantization_is_deterministic() {
    let mut rng = Rng::new(1);
    let w = Matrix::gaussian(32, 32, 0.5, &mut rng);
    let h = Matrix::identity(32);
    let cfg = QtipConfig { l: 10, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 9 };
    let a = quantize_matrix_qtip(&w, &h, &cfg);
    let b = quantize_matrix_qtip(&w, &h, &cfg);
    assert_eq!(a.qm.packed, b.qm.packed);
    assert_eq!(a.qm.scale, b.qm.scale);
}

/// End-to-end error propagation sanity: proxy loss in the incoherent space equals
/// proxy loss in the original space (RHT invariance), measured on a real chain.
#[test]
fn proxy_invariance_through_pipeline() {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 1;
    cfg.max_seq = 32;
    let model = Transformer::from_store(&WeightStore::random(&cfg, 11));
    let seqs = vec![vec![1u16, 3, 5, 7, 9, 11, 13, 15]];
    let hs = collect_hessians(&model, &seqs);
    let h = &hs.by_layer["l0.q"];

    let w = match &model.layers[0].attn.q {
        qtip::model::Linear::Dense(w) => w.clone(),
        _ => unreachable!(),
    };
    let qcfg = QtipConfig { l: 10, k: 3, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 5 };
    let res = quantize_matrix_qtip(&w, &h.clone(), &qcfg);
    // Original-space proxy using reconstructed Ŵ:
    let w_hat = res.qm.reconstruct_w();
    let h_reg = qtip::util::linalg::regularize_spd(h, 1e-2);
    let orig = qtip::quant::proxy::relative_proxy_loss(&w, &w_hat, &h_reg);
    // It should be close to the incoherent-space metric recorded at quantization.
    let tilde = res.metrics.relative_proxy;
    assert!(
        (orig - tilde).abs() < 0.5 * tilde.max(0.01),
        "orig {orig} vs tilde {tilde}"
    );
}

/// Codes must materialize the exact table the hot decode path uses.
#[test]
fn all_codes_materialize_consistently() {
    for name in ["1mad", "3inst", "hyb", "lut"] {
        let v = if name == "hyb" { 2 } else { 1 };
        let code = build_code(name, 12, v, 3);
        let table = code.materialize();
        let mut out = vec![0.0f32; v as usize];
        for s in (0..4096).step_by(37) {
            code.decode(s as u32, &mut out);
            for j in 0..v as usize {
                assert_eq!(table[s * v as usize + j], out[j], "{name} state {s}");
            }
        }
    }
}
