//! Cross-cutting property tests (deliverable c): invariants that span modules,
//! run with the in-repo prop harness (seeded, reproducible).

use qtip::codes::{build_code, Code};
use qtip::quant::{quantize_matrix_qtip, QtipConfig};
use qtip::trellis::packing::{pack_states, pad_for_decode, unpack_states};
use qtip::trellis::{quantize_tail_biting, Trellis, Viterbi, ViterbiWorkspace};
use qtip::util::matrix::Matrix;
use qtip::util::prop::prop_check;

/// Quantization is 1-Lipschitz-ish in MSE: quantizing y=x+eps can't be more than
/// ||eps|| worse than quantizing x (triangle inequality on the nearest-walk set).
#[test]
fn prop_viterbi_stability_under_perturbation() {
    prop_check("viterbi stability", 12, |g| {
        let l = g.usize_in(6, 10) as u32;
        let trellis = Trellis::new(l, 2, 1);
        let values = g.gauss_vec(trellis.states());
        let vit = Viterbi::new(trellis, &values);
        let mut ws = ViterbiWorkspace::new();
        let n = 32;
        let x = g.gauss_vec(n);
        let eps: Vec<f32> = (0..n).map(|_| g.f32_in(-0.01, 0.01)).collect();
        let y: Vec<f32> = x.iter().zip(&eps).map(|(a, b)| a + b).collect();
        let (_, cx) = vit.quantize(&x, None, None, &mut ws);
        let (_, cy) = vit.quantize(&y, None, None, &mut ws);
        let eps_norm: f64 = eps.iter().map(|&e| (e as f64).powi(2)).sum::<f64>().sqrt();
        let bound = (cx.sqrt() + eps_norm).powi(2) + 1e-4;
        assert!(cy <= bound, "cy={cy} > bound={bound}");
    });
}

/// Round-trip: pack -> unpack -> decode == direct decode of the walk, for every
/// (L, k, V) geometry the pipeline supports.
#[test]
fn prop_pack_decode_roundtrip_geometries() {
    prop_check("pack/decode roundtrip", 20, |g| {
        let l = g.usize_in(4, 14) as u32;
        let k = g.usize_in(1, 4) as u32;
        let v = if k * 2 <= 8 && k * 2 < l && g.bool() { 2u32 } else { 1 };
        if k * v >= l || k * v > 8 {
            return;
        }
        let trellis = Trellis::new(l, k, v);
        let values = g.gauss_vec(trellis.states() * v as usize);
        let vit = Viterbi::new(trellis, &values);
        let min_steps = (l as usize).div_ceil((k * v) as usize).max(2);
        let steps = g.usize_in(min_steps, min_steps + 16);
        let seq = g.gauss_vec(steps * v as usize);
        let mut ws = ViterbiWorkspace::new();
        let sol = quantize_tail_biting(&vit, &seq, &mut ws);
        let packed = pack_states(&trellis, &sol.states);
        assert_eq!(unpack_states(&trellis, &packed, steps), sol.states);
        let padded = pad_for_decode(&trellis, &packed, steps);
        for (t, &s) in sol.states.iter().enumerate() {
            let w = qtip::trellis::packing::decode_window(
                &padded,
                t * (k * v) as usize,
                l,
            );
            assert_eq!(w, s);
        }
    });
}

/// The quantized artifact's matvec is linear: Q(ax + by) == a·Q(x) + b·Q(y).
#[test]
fn prop_quantized_matvec_linearity() {
    prop_check("qmatvec linear", 6, |g| {
        let cfg = QtipConfig {
            l: 10,
            k: 2,
            v: 1,
            tx: 8,
            ty: 8,
            code: "3inst".into(),
            seed: g.rng.next_u64(),
        };
        let mut m = Matrix::zeros(16, 16);
        for v in m.data.iter_mut() {
            *v = g.f32_in(-1.0, 1.0);
        }
        let h = Matrix::identity(16);
        let qm = quantize_matrix_qtip(&m, &h, &cfg).qm;
        let x = g.gauss_vec(16);
        let y = g.gauss_vec(16);
        let (a, b) = (g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0));
        let combo: Vec<f32> = x.iter().zip(&y).map(|(&p, &q)| a * p + b * q).collect();
        let lhs = qm.matvec(&combo);
        let rx = qm.matvec(&x);
        let ry = qm.matvec(&y);
        for i in 0..16 {
            let rhs = a * rx[i] + b * ry[i];
            assert!((lhs[i] - rhs).abs() < 1e-2, "{} vs {}", lhs[i], rhs);
        }
    });
}

/// Every code's decode is a pure function of the state (no hidden state).
#[test]
fn prop_codes_are_pure() {
    prop_check("codes pure", 10, |g| {
        for name in ["1mad", "3inst", "hyb", "lut"] {
            let v = if name == "hyb" { 2 } else { 1 };
            let code = build_code(name, 12, v, 7);
            let s = g.usize_in(0, 4095) as u32;
            let mut a = vec![0.0f32; v as usize];
            let mut b = vec![1.0f32; v as usize];
            code.decode(s, &mut a);
            code.decode(s, &mut b);
            assert_eq!(a, b, "{name}");
        }
    });
}

/// Viterbi solution cost is monotone in L (more states can only help) when
/// codebooks are nested (the LUT code with the same seed is a prefix).
#[test]
fn prop_more_bits_never_hurt() {
    prop_check("k monotone", 6, |g| {
        let trellis_lo = Trellis::new(10, 1, 1);
        let trellis_hi = Trellis::new(10, 2, 1);
        let values = g.gauss_vec(1 << 10);
        let vit_lo = Viterbi::new(trellis_lo, &values);
        let vit_hi = Viterbi::new(trellis_hi, &values);
        let seq = g.gauss_vec(32);
        let mut ws = ViterbiWorkspace::new();
        // Same states, more edges: k=2's walk set strictly contains k=1's...
        // (every (i -> i>>1 | c<<9) edge is also reachable with 2-bit shifts? No —
        // different shift amounts. So compare both against the elementwise bound
        // instead: higher fan-out must beat scalar nearest-neighbor rounding of
        // half the codebook.)
        let (_, c_lo) = vit_lo.quantize(&seq, None, None, &mut ws);
        let (_, c_hi) = vit_hi.quantize(&seq, None, None, &mut ws);
        // Sanity: both bounded below by the unconstrained nearest-value error.
        let free: f64 = seq
            .iter()
            .map(|&s| {
                values
                    .iter()
                    .map(|&v| ((v - s) as f64).powi(2))
                    .fold(f64::INFINITY, f64::min)
            })
            .sum();
        assert!(c_lo >= free - 1e-5);
        assert!(c_hi >= free - 1e-5);
        assert!(c_hi <= c_lo + 1e-5, "more transition bits should not hurt");
    });
}
