//! Thread-count invariance of the tile-parallel decode stack.
//!
//! The `ExecPool` kernels stripe disjoint row-tile bands across workers but
//! never reorder any per-row accumulation, so every parallel path must be
//! **bit-identical** to its sequential counterpart — across every registered
//! quant method and pool widths 1, 2, 4. A serving determinism test under a
//! multi-worker pool lives in `coordinator::server::tests`.

use qtip::coordinator::quantize_model_qtip;
use qtip::hessian::collect_hessians;
use qtip::model::transformer::DecodeScratch;
use qtip::model::{KvCache, ModelConfig, Transformer, WeightStore};
use qtip::quant::{registry, CodeSpec, QtipConfig, QuantizedMatrix};
use qtip::trellis::Trellis;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;

const WIDTHS: [usize; 3] = [1, 2, 4];

fn synthetic_specs() -> Vec<(&'static str, Trellis, CodeSpec)> {
    registry::all()
        .iter()
        .map(|m| {
            let (trellis, spec) = m.synthetic_entry(12, 2, 5);
            (m.name(), trellis, spec)
        })
        .collect()
}

#[test]
fn matvec_tilde_pool_is_bit_identical_across_widths() {
    // 4 tile rows × 2 tile cols so bands genuinely split across workers.
    for (name, trellis, code) in synthetic_specs() {
        let qm = QuantizedMatrix::synthetic(64, 32, trellis, code, 16, 16, 7);
        let mut rng = Rng::new(17);
        let x = rng.gauss_vec(32);
        let mut seq = vec![0.0f32; 64];
        qm.matvec_tilde(&x, &mut seq);
        for width in WIDTHS {
            let pool = ExecPool::new(width);
            let mut par = vec![0.0f32; 64];
            qm.matvec_tilde_pool(&x, &mut par, &pool);
            assert_eq!(seq, par, "{name}: matvec_tilde diverged at width {width}");
        }
    }
}

#[test]
fn matvec_tilde_multi_pool_is_bit_identical_across_widths() {
    for (name, trellis, code) in synthetic_specs() {
        let qm = QuantizedMatrix::synthetic(64, 32, trellis, code, 16, 16, 9);
        let mut rng = Rng::new(23);
        let b = 5usize;
        let mut x = Matrix::zeros(b, 32);
        for r in 0..b {
            let xr = rng.gauss_vec(32);
            x.row_mut(r).copy_from_slice(&xr);
        }
        let mut seq = Matrix::zeros(b, 64);
        qm.matvec_tilde_multi(&x, &mut seq);
        for width in WIDTHS {
            let pool = ExecPool::new(width);
            let mut par = Matrix::zeros(b, 64);
            let mut xcol = Vec::new();
            qm.matvec_tilde_multi_pool(&x, &mut par, &mut xcol, &pool);
            assert_eq!(seq.data, par.data, "{name}: multi kernel diverged at width {width}");
        }
        // And every fused row must still equal the single-column kernel.
        for r in 0..b {
            let mut single = vec![0.0f32; 64];
            qm.matvec_tilde(x.row(r), &mut single);
            assert_eq!(seq.row(r), &single[..], "{name}: fused row {r} != single");
        }
    }
}

fn tiny_quantized(code: &str, v: u32) -> Transformer {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 32;
    cfg.name = "tiny".into();
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 31));
    let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v, tx: 8, ty: 8, code: code.into(), seed: 77 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    model
}

#[test]
fn decode_logits_bit_identical_across_widths_all_codes() {
    // End-to-end: full quantized decode steps through the scratch arena must
    // produce logits bit-identical to the sequential `decode_step`, for every
    // registered method and every pool width.
    let tokens = [10u16, 200, 37, 99];
    for (code, v) in registry::all().iter().map(|m| (m.name(), m.preferred_v())) {
        let model = tiny_quantized(code, v);
        let mut ref_cache = KvCache::new(&model.cfg);
        let reference: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.decode_step(&mut ref_cache, t)).collect();
        for width in WIDTHS {
            let pool = ExecPool::new(width);
            let mut scratch = DecodeScratch::new(&model.cfg);
            let mut cache = KvCache::new(&model.cfg);
            for (pos, &t) in tokens.iter().enumerate() {
                let logits = model.decode_step_with(&mut cache, t, &mut scratch, &pool);
                assert_eq!(
                    logits,
                    &reference[pos][..],
                    "{code}: decode_step_with diverged at width {width}, pos {pos}"
                );
            }
        }
    }
}

#[test]
fn batch_decode_bit_identical_across_widths() {
    // Fused batch rounds under a multi-worker pool vs per-sequence sequential
    // decode — heterogeneous prefixes, every width.
    let model = tiny_quantized("3inst", 1);
    let streams: [&[u16]; 3] = [&[10, 200, 37, 99, 5], &[7, 7, 42], &[250]];
    let mut reference: Vec<Vec<Vec<f32>>> = Vec::new();
    for s in &streams {
        let mut cache = KvCache::new(&model.cfg);
        reference.push(s.iter().map(|&t| model.decode_step(&mut cache, t)).collect());
    }
    for width in WIDTHS {
        let pool = ExecPool::new(width);
        let mut scratch = DecodeScratch::new(&model.cfg);
        let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&model.cfg)).collect();
        let max_len = streams.iter().map(|s| s.len()).max().unwrap();
        for pos in 0..max_len {
            let mut tokens = Vec::new();
            let mut idxs = Vec::new();
            for (i, s) in streams.iter().enumerate() {
                if pos < s.len() {
                    tokens.push(s[pos]);
                    idxs.push(i);
                }
            }
            let mut refs: Vec<&mut KvCache> = Vec::new();
            for (i, c) in caches.iter_mut().enumerate() {
                if idxs.contains(&i) {
                    refs.push(c);
                }
            }
            let logits = model.decode_step_batch_with(&mut refs, &tokens, &mut scratch, &pool);
            for (j, &i) in idxs.iter().enumerate() {
                assert_eq!(
                    logits.row(j),
                    &reference[i][pos][..],
                    "width {width}: seq {i} pos {pos} diverged"
                );
            }
        }
    }
}

#[test]
fn forward_batch_bit_identical_across_widths() {
    let cfg = {
        let mut c = ModelConfig::nano();
        c.d_model = 32;
        c.n_heads = 2;
        c.d_ff = 64;
        c.n_layers = 2;
        c.max_seq = 32;
        c
    };
    let model = Transformer::from_store(&WeightStore::random(&cfg, 41));
    let tokens = [1u16, 9, 77, 200, 3];
    let seq = model.forward_batch(&tokens);
    for width in WIDTHS {
        let pool = ExecPool::new(width);
        let par = model.forward_batch_with(&tokens, &pool);
        assert_eq!(seq.data, par.data, "forward_batch diverged at width {width}");
    }
}
