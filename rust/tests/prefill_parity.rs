//! Chunked-prefill bit-identity: [`Transformer::prefill_chunk_paged`] must
//! leave exactly the K/V rows, final logits, and continued greedy stream of
//! token-at-a-time paged decode, for every registered quant method, both
//! decode-kernel families, chunk sizes {1, 3, kv_block, ≥ prompt}, and pool
//! widths 1/2. A deliberately tiny block size (4 positions) makes every
//! multi-token chunk straddle KV-block boundaries.
//!
//! The server-level suite at the bottom drives the real [`ServerHandle`]
//! scheduler with chunking on vs off over the prefix-sharing divergence
//! shapes (block-boundary, mid-block, and the exact-full-match prompt that
//! forces the admission copy-on-write reserve): token streams, aliasing
//! counts, and the CoW count must all be unchanged by the chunk size.

use std::sync::Arc;

use qtip::coordinator::{
    quantize_model_qtip, GenRequest, ServerConfig, ServerHandle, ServerStats,
};
use qtip::hessian::collect_hessians;
use qtip::model::{
    DecodeScratch, KvArena, KvLayout, KvSeq, ModelConfig, Transformer, WeightStore,
};
use qtip::quant::{registry, KernelKind, QtipConfig};
use qtip::util::threadpool::ExecPool;

const BLOCK: usize = 4;
const WIDTHS: [usize; 2] = [1, 2];
/// 11 tokens: not a multiple of any tested chunk size except 1, so the
/// chunk-3 and chunk-4 sweeps end on ragged tails (3+3+3+2, 4+4+3).
const PROMPT: [u16; 11] = [10, 200, 37, 99, 5, 7, 7, 140, 3, 88, 250];
const DECODE_STEPS: usize = 6;

/// Every registered method as a (code name, V) quantizer config — iterating
/// the registry keeps this sweep complete as methods are added.
fn codes() -> Vec<(&'static str, u32)> {
    registry::all().iter().map(|m| (m.name(), m.preferred_v())).collect()
}

fn quantized_tiny(code: &str, v: u32) -> Transformer {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 64;
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 21));
    let seqs = vec![(0..48u16).collect::<Vec<_>>(), (60..108u16).collect::<Vec<_>>()];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v, tx: 8, ty: 8, code: code.into(), seed: 5 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    model
}

/// Snapshot of every K/V row a sequence holds — the bit-identity claim is on
/// the cache contents, not just the logits that happen to read them.
fn kv_snapshot(arena: &KvArena, seq: &KvSeq, n_layers: usize) -> Vec<Vec<f32>> {
    let mut rows = Vec::new();
    for li in 0..n_layers {
        for pos in 0..seq.len {
            rows.push(arena.k_row(seq, li, pos).to_vec());
            rows.push(arena.v_row(seq, li, pos).to_vec());
        }
    }
    rows
}

/// Greedy continuation for `DECODE_STEPS` tokens from `logits`, decoding
/// token-at-a-time (both runs share this tail, so any divergence it reports
/// was introduced during prefill).
fn greedy_tail(
    model: &Transformer,
    arena: &mut KvArena,
    seq: &mut KvSeq,
    scratch: &mut DecodeScratch,
    pool: &ExecPool,
    logits: &[f32],
) -> Vec<u16> {
    let mut rng = qtip::util::rng::Rng::new(1);
    let mut tokens = Vec::new();
    let mut next = Transformer::sample(logits, 0.0, 1, &mut rng);
    for _ in 0..DECODE_STEPS {
        tokens.push(next);
        let need = seq.len + 1;
        assert!(arena.ensure(seq, need), "arena sized for the whole run");
        let mut refs = [&mut *seq];
        let m = model.decode_step_batch_paged(arena, &mut refs, &[next], scratch, pool);
        next = Transformer::sample(m.row(0), 0.0, 1, &mut rng);
    }
    tokens
}

/// Reference: the prompt ingested one position per pass over the paged arena.
fn token_at_a_time(
    model: &Transformer,
    pool: &ExecPool,
) -> (Vec<Vec<f32>>, Vec<f32>, Vec<u16>) {
    let mut arena = KvArena::new(&model.cfg, BLOCK, model.cfg.max_seq.div_ceil(BLOCK));
    let mut seq = KvSeq::new();
    let mut scratch = DecodeScratch::new(&model.cfg);
    let mut logits: Vec<f32> = Vec::new();
    for &t in &PROMPT {
        let need = seq.len + 1;
        assert!(arena.ensure(&mut seq, need), "arena sized for the prompt");
        let mut refs = [&mut seq];
        let m = model.decode_step_batch_paged(&mut arena, &mut refs, &[t], &mut scratch, pool);
        logits = m.row(0).to_vec();
    }
    let snap = kv_snapshot(&arena, &seq, model.cfg.n_layers);
    let tokens = greedy_tail(model, &mut arena, &mut seq, &mut scratch, pool, &logits);
    (snap, logits, tokens)
}

/// The same prompt ingested through [`Transformer::prefill_chunk_paged`] in
/// chunks of `chunk` positions (ragged final chunk included).
fn chunked(
    model: &Transformer,
    chunk: usize,
    pool: &ExecPool,
) -> (Vec<Vec<f32>>, Vec<f32>, Vec<u16>) {
    let mut arena = KvArena::new(&model.cfg, BLOCK, model.cfg.max_seq.div_ceil(BLOCK));
    let mut seq = KvSeq::new();
    let mut scratch = DecodeScratch::new(&model.cfg);
    let mut logits: Vec<f32> = Vec::new();
    let mut off = 0usize;
    while off < PROMPT.len() {
        let take = chunk.min(PROMPT.len() - off);
        let need = seq.len + take;
        assert!(arena.ensure(&mut seq, need), "arena sized for the chunk");
        logits = model
            .prefill_chunk_paged(&mut arena, &mut seq, &PROMPT[off..off + take], &mut scratch, pool)
            .to_vec();
        off += take;
    }
    assert_eq!(seq.len, PROMPT.len(), "chunked prefill must consume the whole prompt");
    let snap = kv_snapshot(&arena, &seq, model.cfg.n_layers);
    let tokens = greedy_tail(model, &mut arena, &mut seq, &mut scratch, pool, &logits);
    (snap, logits, tokens)
}

#[test]
fn chunked_prefill_matches_token_at_a_time_for_all_codes_kernels_widths() {
    for (code, v) in codes() {
        let mut model = quantized_tiny(code, v);
        for kernel in [KernelKind::Scalar, KernelKind::Lanes] {
            model.set_decode_kernel(kernel);
            for width in WIDTHS {
                let pool = ExecPool::new(width);
                let (ref_snap, ref_logits, ref_tokens) = token_at_a_time(&model, &pool);
                for chunk in [1usize, 3, BLOCK, PROMPT.len()] {
                    let (snap, logits, tokens) = chunked(&model, chunk, &pool);
                    let tag = format!("{code} kernel={} width={width} chunk={chunk}", kernel.name());
                    assert_eq!(snap, ref_snap, "{tag}: chunked prefill wrote different K/V rows");
                    assert_eq!(logits, ref_logits, "{tag}: final prefill logits diverged");
                    assert_eq!(tokens, ref_tokens, "{tag}: continued greedy stream diverged");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Server-level parity: chunking on vs off through the real scheduler, over
// prefix-aliased blocks and the CoW divergence shapes.
// ---------------------------------------------------------------------------

/// 12 bytes = exactly 3 whole blocks at the 4-position test block size, so a
/// prompt that is the prefix alone fully matches the index (the CoW case).
const SHARED_PREFIX: &str = "SYSTEM: do x";

fn req(id: u64, prompt: &str, max_new: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: prompt.to_string(),
        max_new_tokens: max_new,
        temperature: 0.0,
        top_k: 1,
        seed: id,
        model: String::new(),
        deadline_ms: 0,
    }
}

/// Serve the prefix-divergence jobs (seed alone first so its blocks are
/// index-resident, then the three sharers) with the given chunk geometry;
/// returns per-request token streams and the final stats.
fn serve_prefix_jobs(
    model: &Arc<Transformer>,
    threads: usize,
    prefill_chunk: usize,
    jobs: &[GenRequest],
) -> (Vec<Vec<u16>>, ServerStats) {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 4,
            threads,
            kv_layout: KvLayout::Paged,
            kv_block: BLOCK,
            prefix_share: true,
            prefill_chunk,
            ..Default::default()
        },
    );
    let r0 = server.submit(jobs[0].clone()).recv().expect("seed served");
    assert!(r0.error.is_none(), "seed rejected: {:?}", r0.error);
    let rxs: Vec<_> = jobs[1..].iter().map(|j| server.submit(j.clone())).collect();
    let mut got = vec![r0.tokens];
    for rx in rxs {
        let r = rx.recv().expect("sharer served");
        assert!(r.error.is_none(), "sharer rejected: {:?}", r.error);
        got.push(r.tokens);
    }
    (got, server.shutdown())
}

/// Chunk boundaries must compose with prefix aliasing: only the un-aliased
/// prompt tail is chunked, divergence mid-block and on block boundaries
/// included, and the full-match prompt's copy-on-write still fires exactly
/// once — with token streams identical to the token-at-a-time scheduler.
#[test]
fn chunked_prefill_is_bit_identical_over_aliased_blocks_and_cow() {
    let jobs = vec![
        req(0, &format!("{SHARED_PREFIX}A1"), 6),
        // Divergence at position 12 — the first block boundary past the prefix.
        req(1, &format!("{SHARED_PREFIX}B2"), 6),
        // Divergence at position 10 — inside block 2, so only 2 blocks alias.
        req(2, &format!("{}zzzz", &SHARED_PREFIX[..10]), 6),
        // The prefix alone: all 3 blocks alias, the cursor re-enters the last
        // shared block, and the first decode round must copy-on-write it.
        req(3, SHARED_PREFIX, 6),
    ];
    let (code, v) = codes()[1];
    let model = Arc::new(quantized_tiny(code, v));
    for threads in [1usize, 2] {
        let (reference, base_stats) = serve_prefix_jobs(&model, threads, 1, &jobs);
        assert_eq!(
            base_stats.prefill_chunks, 0,
            "threads={threads}: chunk 1 must stay on the fused token-at-a-time path"
        );
        // Chunk 3 splits the seed prompt mid-block, BLOCK aligns chunks to
        // block boundaries, 32 swallows every prompt whole.
        for chunk in [3usize, BLOCK, 32] {
            let (got, stats) = serve_prefix_jobs(&model, threads, chunk, &jobs);
            assert_eq!(
                got, reference,
                "threads={threads} chunk={chunk}: chunked prefill diverged over \
                 prefix-aliased admission"
            );
            assert!(
                stats.prefill_chunks > 0,
                "threads={threads} chunk={chunk}: no prompt went through the GEMM path"
            );
            assert_eq!(
                stats.prefix_hits, 3,
                "threads={threads} chunk={chunk}: every sharer must still hit the index"
            );
            assert_eq!(
                stats.blocks_shared, 8,
                "threads={threads} chunk={chunk}: 3+2+3 blocks must still alias"
            );
            assert_eq!(
                stats.cow_copies, 1,
                "threads={threads} chunk={chunk}: the full-match prompt must CoW once"
            );
            assert_eq!(stats.completed, jobs.len());
        }
    }
}
