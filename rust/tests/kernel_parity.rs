//! Lane-kernel parity: the lane-blocked decode kernels (§Perf optimization
//! #2) must be **bit-identical** to the scalar reference kernels for every
//! registered quant method, every entry point (single-column, batch-fused,
//! pooled), and every pool width — including lane-boundary shapes where
//! `tiles_r · tx` is not a multiple of `LANES`, which exercise the padded
//! remainder blocks. A cold-started artifact served under `scalar` and under
//! the default (`auto` → `lanes`) must emit identical tokens.
//!
//! The sweeps iterate `quant::registry` rather than a hardcoded method list,
//! so a newly registered method is parity-checked with zero edits here.

use std::path::PathBuf;
use std::sync::Arc;

use qtip::coordinator::{quantize_model_qtip, GenRequest, ServerConfig, ServerHandle};
use qtip::hessian::collect_hessians;
use qtip::model::{ModelConfig, Transformer, WeightStore};
use qtip::quant::{
    kernel, quantize_matrix_qtip, registry, CodeSpec, KernelKind, LANES, QtipConfig,
    QuantizedMatrix,
};
use qtip::trellis::Trellis;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;

const WIDTHS: [usize; 3] = [1, 2, 4];

/// Every registered method's synthetic spec on an L=12 trellis (covers both
/// the V=1 and V=2 decode paths).
fn synthetic_specs() -> Vec<(&'static str, Trellis, CodeSpec)> {
    registry::all()
        .iter()
        .map(|m| {
            let (trellis, spec) = m.synthetic_entry(12, 2, 5);
            (m.name(), trellis, spec)
        })
        .collect()
}

fn batch(rng: &mut Rng, b: usize, cols: usize) -> Matrix {
    let mut x = Matrix::zeros(b, cols);
    for r in 0..b {
        let xr = rng.gauss_vec(cols);
        x.row_mut(r).copy_from_slice(&xr);
    }
    x
}

#[test]
fn lane_kernels_bit_identical_on_lane_boundary_shapes() {
    // tx = 4 so row counts 4, 12, 20 are all non-multiples of LANES (8):
    // full lane blocks, a half block, and a block-and-a-half of remainder.
    let (tx, ty, cols) = (4usize, 8usize, 32usize);
    for rows in [4usize, 12, 20] {
        assert_ne!(rows % LANES, 0, "shape must exercise the remainder block");
        for (name, trellis, code) in synthetic_specs() {
            let mut qm =
                QuantizedMatrix::synthetic(rows, cols, trellis, code, tx, ty, rows as u64);
            let mut rng = Rng::new(rows as u64 + 100);
            let x = rng.gauss_vec(cols);

            qm.kernel = KernelKind::Scalar;
            let mut y_scalar = vec![0.0f32; rows];
            qm.matvec_tilde(&x, &mut y_scalar);
            qm.kernel = KernelKind::Lanes;
            let mut y_lanes = vec![0.0f32; rows];
            qm.matvec_tilde(&x, &mut y_lanes);
            assert_eq!(y_scalar, y_lanes, "{name} rows={rows}: single-column diverged");

            // Batch-fused: one chunk and wider-than-BCHUNK batches.
            for b in [3usize, 18] {
                let xm = batch(&mut rng, b, cols);
                qm.kernel = KernelKind::Scalar;
                let mut m_scalar = Matrix::zeros(b, rows);
                qm.matvec_tilde_multi(&xm, &mut m_scalar);
                qm.kernel = KernelKind::Lanes;
                let mut m_lanes = Matrix::zeros(b, rows);
                qm.matvec_tilde_multi(&xm, &mut m_lanes);
                assert_eq!(
                    m_scalar.data, m_lanes.data,
                    "{name} rows={rows} b={b}: batch-fused diverged"
                );
            }
        }
    }
}

#[test]
fn lane_kernels_bit_identical_under_pool_striping() {
    // Pooled entry points: lane-block-aligned bands across every width must
    // match the sequential scalar kernel bit-for-bit, on a shape whose band
    // count is not a multiple of the worker count.
    let (rows, cols, tx, ty) = (20usize, 32usize, 4usize, 8usize);
    for (name, trellis, code) in synthetic_specs() {
        let mut qm = QuantizedMatrix::synthetic(rows, cols, trellis, code, tx, ty, 5);
        let mut rng = Rng::new(51);
        let x = rng.gauss_vec(cols);
        let xm = batch(&mut rng, 5, cols);

        qm.kernel = KernelKind::Scalar;
        let mut y_ref = vec![0.0f32; rows];
        qm.matvec_tilde(&x, &mut y_ref);
        let mut m_ref = Matrix::zeros(5, rows);
        qm.matvec_tilde_multi(&xm, &mut m_ref);

        qm.kernel = KernelKind::Lanes;
        for width in WIDTHS {
            let pool = ExecPool::new(width);
            let mut y = vec![0.0f32; rows];
            qm.matvec_tilde_pool(&x, &mut y, &pool);
            assert_eq!(y_ref, y, "{name} width={width}: pooled single-column diverged");
            let mut m = Matrix::zeros(5, rows);
            let mut xcol = Vec::new();
            qm.matvec_tilde_multi_pool(&xm, &mut m, &mut xcol, &pool);
            assert_eq!(m_ref.data, m.data, "{name} width={width}: pooled batch diverged");
        }
    }
}

#[test]
fn quantized_rht_sandwich_is_kernel_invariant() {
    // Through the real quantization pipeline (RHT + BlockLDLQ + packing) on a
    // lane-boundary shape: the full `matvec` sandwich must not care which
    // kernel family decodes.
    let mut rng = Rng::new(61);
    let w = Matrix::gaussian(12, 16, 0.5, &mut rng);
    // A light SPD proxy Hessian.
    let mut h = Matrix::zeros(16, 16);
    let a = Matrix::gaussian(16, 32, 1.0, &mut rng);
    for i in 0..16 {
        for j in 0..16 {
            let mut s = 0.0;
            for k in 0..32 {
                s += a.at(i, k) * a.at(j, k);
            }
            *h.at_mut(i, j) = s / 32.0;
        }
    }
    for m in registry::all() {
        let code = m.name();
        let cfg = QtipConfig {
            l: 10,
            k: 2,
            v: m.preferred_v(),
            tx: 4,
            ty: 8,
            code: code.into(),
            seed: 63,
        };
        let mut qm = quantize_matrix_qtip(&w, &h, &cfg).qm;
        let x = rng.gauss_vec(16);
        qm.kernel = KernelKind::Scalar;
        let y_scalar = qm.matvec(&x);
        qm.kernel = KernelKind::Lanes;
        let y_lanes = qm.matvec(&x);
        assert_eq!(y_scalar, y_lanes, "{code}: RHT-sandwich matvec diverged");
    }
}

fn tiny_quantized_model() -> (Transformer, qtip::coordinator::QuantizeReport) {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 1;
    cfg.max_seq = 64;
    cfg.name = "tiny".into();
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 19));
    let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 23 };
    let report =
        quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    (model, report)
}

fn serve_tokens(model: Transformer, expect_kernel: &str) -> Vec<Vec<u16>> {
    let server = ServerHandle::spawn(Arc::new(model), ServerConfig::default());
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            server.submit(GenRequest {
                id: i,
                prompt: format!("prompt {i}"),
                max_new_tokens: 8,
                temperature: 0.8,
                top_k: 16,
                seed: 300 + i,
                model: String::new(),
                deadline_ms: 0,
            })
        })
        .collect();
    let out: Vec<Vec<u16>> = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
    let stats = server.shutdown();
    assert_eq!(stats.kernel, expect_kernel, "ServerStats must report the pinned kernel");
    out
}

#[test]
fn artifact_serve_is_kernel_invariant() {
    // The QTIP_KERNEL=scalar vs auto serving contract, exercised through the
    // full save → cold-start-load → serve path: identical artifacts pinned to
    // the scalar and lane families must stream identical tokens.
    let dir: PathBuf = std::env::temp_dir()
        .join(format!("qtip_kernel_parity_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let (model, report) = tiny_quantized_model();
    qtip::io::save_quantized_model(&dir, "kp", &model, &report).unwrap();
    drop(model);

    let (mut scalar_model, _, _) = qtip::io::load_quantized_model(&dir, "kp").unwrap();
    scalar_model.ensure_caches();
    scalar_model.set_decode_kernel(KernelKind::Scalar);
    assert_eq!(scalar_model.decode_kernel(), Some(KernelKind::Scalar));

    let (mut lanes_model, _, _) = qtip::io::load_quantized_model(&dir, "kp").unwrap();
    lanes_model.ensure_caches();
    // `Auto` resolves to the lane family — the serving default.
    lanes_model.set_decode_kernel(KernelKind::Auto);
    assert_eq!(lanes_model.decode_kernel(), Some(KernelKind::Lanes));

    let scalar_tokens = serve_tokens(scalar_model, "scalar");
    let lanes_tokens = serve_tokens(lanes_model, "lanes");
    assert_eq!(scalar_tokens, lanes_tokens, "served tokens changed with the kernel family");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn selection_and_banding_contract() {
    // The precedence rule and lane-band alignment the CLI and pool paths rely
    // on (unit tests in quant::kernel cover the full matrix; this pins the
    // public API from an integration consumer's viewpoint).
    assert_eq!(kernel::select(Some(KernelKind::Scalar), Some("lanes")), KernelKind::Scalar);
    assert_eq!(kernel::select(None, Some("scalar")), KernelKind::Scalar);
    assert_eq!(kernel::select(None, None), KernelKind::Auto);
    assert_eq!(KernelKind::Auto.resolve(), KernelKind::Lanes);
    for tx in [1usize, 4, 8, 16, 32] {
        assert!(kernel::lane_band_tiles(tx) * tx >= LANES);
    }
}
