//! Deterministic fault-injection soak against the real serving stack.
//!
//! Each test wires a seeded [`FaultPlan`] (`util::fault`) into the server and
//! asserts the overload-hardening invariants the batcher promises:
//!
//!   1. no deadlock — every submitted request terminates within a generous
//!      wall-clock bound (receiver timeouts are the deadlock detector);
//!   2. every request ends in exactly one of: a full token stream, or a
//!      structured error with a stable machine-readable code;
//!   3. faults never corrupt accepted output — tokens produced under
//!      injected allocation failures are identical to a fault-free run
//!      (greedy decoding, so any divergence is corruption, not sampling);
//!   4. the KV arena's partition invariant (free ⊎ leased ⊎ shared = pool)
//!      holds after every round — asserted internally by the debug build at
//!      round boundaries, so simply completing under chaos exercises it.
//!
//! The last test additionally honors a `QTIP_FAULT=<seed>:<spec>` schedule
//! from the environment (the CI chaos lane's seed matrix); without the
//! variable it runs the same soak fault-free, so plain `cargo test` stays
//! deterministic.

use std::sync::Arc;
use std::time::Duration;

use qtip::coordinator::{
    codes, quantize_model_qtip, GenRequest, ServerConfig, ServerHandle,
};
use qtip::hessian::collect_hessians;
use qtip::model::{KvArena, KvLayout, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::fault::FaultPlan;
use qtip::util::threadpool::ExecPool;

/// Generous per-request bound: far above any real decode time for the tiny
/// model, tight enough that a wedged batcher fails the suite instead of
/// hanging it.
const DEADLOCK_BOUND: Duration = Duration::from_secs(60);

fn quantized_tiny() -> Arc<Transformer> {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 96;
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 13));
    let seqs = vec![
        (0..64u16).collect::<Vec<_>>(),
        (100..164u16).collect::<Vec<_>>(),
    ];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 2 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    Arc::new(model)
}

fn req(id: u64, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("chaos-{id}"),
        max_new_tokens: n,
        temperature: 0.0,
        top_k: 1,
        seed: id,
        model: String::new(),
        deadline_ms: 0,
    }
}

/// A tight paged config: small blocks and an arena that just covers one full
/// sequence, so injected allocation failures actually exercise the
/// reclaim → stall → evict relief ladder instead of disappearing into slack.
fn tight_paged_cfg(model: &Transformer) -> ServerConfig {
    let block = 8usize;
    let budget = model.cfg.max_seq.div_ceil(block) * KvArena::block_bytes(&model.cfg, block);
    ServerConfig {
        max_batch: 3,
        kv_budget_bytes: budget,
        kv_block: block,
        kv_layout: KvLayout::Paged,
        ..Default::default()
    }
}

/// Outcome classifier shared by the soaks: a response is OK iff it carries a
/// full token stream or a structured error with a known code. Anything else
/// (silent truncation, unknown code) is a harness failure.
fn assert_terminated(resp: &qtip::coordinator::GenResponse, want_tokens: usize) {
    match &resp.error {
        None => assert_eq!(
            resp.tokens.len(),
            want_tokens,
            "request {} completed with a truncated stream",
            resp.id
        ),
        Some(err) => {
            let known = [
                codes::BAD_REQUEST,
                codes::UNKNOWN_MODEL,
                codes::KV_BUDGET,
                codes::QUEUE_FULL,
                codes::DEADLINE_EXCEEDED,
                codes::LANE_FAILED,
                codes::SERVER_SHUTDOWN,
            ];
            assert!(
                known.contains(&err.code),
                "request {} failed with unknown code '{}': {}",
                resp.id,
                err.code,
                err.message
            );
        }
    }
}

#[test]
fn alloc_faults_never_corrupt_output_and_every_request_terminates() {
    let model = quantized_tiny();
    // Fault-free reference streams (greedy): the chaos runs must reproduce
    // these bit-exactly for every request they complete.
    let reference: Vec<Vec<u16>> = (0..8)
        .map(|i| {
            let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
            let r = solo.submit(req(i, 4 + 3 * (i as usize % 4))).recv().unwrap();
            solo.shutdown();
            assert!(r.error.is_none());
            r.tokens
        })
        .collect();

    for seed in [11u64, 23, 47] {
        let plan = FaultPlan::parse(&format!("{seed}:kv_alloc=0.3")).unwrap();
        let mut cfg = tight_paged_cfg(&model);
        cfg.fault = Some(Arc::new(plan));
        let server = ServerHandle::spawn(model.clone(), cfg);
        let rxs: Vec<_> =
            (0..8).map(|i| server.submit(req(i, 4 + 3 * (i as usize % 4)))).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx
                .recv_timeout(DEADLOCK_BOUND)
                .unwrap_or_else(|_| panic!("seed {seed}: request {i} never terminated"));
            // No deadlines and transient faults: every request must finish
            // with tokens, and those tokens must match the fault-free run.
            assert!(
                resp.error.is_none(),
                "seed {seed}: request {i} failed: {:?}",
                resp.error
            );
            assert_eq!(
                resp.tokens, reference[i],
                "seed {seed}: injected alloc faults corrupted request {i}"
            );
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 8, "seed {seed}");
    }
}

#[test]
fn decode_panic_poisons_one_lane_and_spares_the_other() {
    let model = quantized_tiny();
    let plan = FaultPlan::parse("3:decode_panic@beta=1.0").unwrap();
    let mut cfg = ServerConfig { max_batch: 2, ..Default::default() };
    cfg.fault = Some(Arc::new(plan));
    let server = ServerHandle::spawn_multi(
        vec![("alpha".to_string(), model.clone()), ("beta".to_string(), model)],
        cfg,
    );
    let to = |id: u64, lane: &str| {
        let mut r = req(id, 6);
        r.model = lane.to_string();
        r
    };
    // Interleave both lanes: beta's poisoning must not take alpha down.
    let beta_rxs: Vec<_> = (0..3).map(|i| server.submit(to(i, "beta"))).collect();
    let alpha_rxs: Vec<_> = (10..13).map(|i| server.submit(to(i, "alpha"))).collect();
    for rx in beta_rxs {
        let resp = rx.recv_timeout(DEADLOCK_BOUND).expect("beta request never terminated");
        let err = resp.error.expect("beta always panics; its requests must all fail");
        assert_eq!(err.code, codes::LANE_FAILED, "{err}");
    }
    for rx in alpha_rxs {
        let resp = rx.recv_timeout(DEADLOCK_BOUND).expect("alpha request never terminated");
        assert!(resp.error.is_none(), "alpha must be unaffected: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
    }
    let health = server.health().expect("batcher must keep answering probes");
    assert!(health.degraded() && !health.all_failed());
    let stats = server.shutdown();
    assert_eq!(stats.lane_panics, 1, "one panic poisons the lane; later requests are rejected");
    assert_eq!(stats.completed, 3);
}

#[test]
fn pool_worker_panic_surfaces_as_lane_failure_without_wedging_the_batcher() {
    let model = quantized_tiny();
    // Every ExecPool band panics mid-run: the submitting lane re-panics, its
    // catch_unwind poisons the lane, and the batcher itself must stay alive.
    let plan = FaultPlan::parse("17:pool_panic=1.0").unwrap();
    let mut cfg = ServerConfig { max_batch: 2, threads: 2, ..Default::default() };
    cfg.fault = Some(Arc::new(plan));
    let server = ServerHandle::spawn(model, cfg);
    let resp = server
        .submit(req(1, 6))
        .recv_timeout(DEADLOCK_BOUND)
        .expect("a pool worker panic must fail the request, not wedge it");
    let err = resp.error.expect("the worker panic must surface as a structured error");
    assert_eq!(err.code, codes::LANE_FAILED, "{err}");
    // The batcher outlives its lane's death: probes still answer and later
    // submissions fail fast with the same structured code instead of queuing
    // behind a corpse.
    let health = server.health().expect("batcher must keep answering probes");
    assert!(health.degraded(), "a poisoned lane must show up in health");
    let resp2 = server
        .submit(req(2, 4))
        .recv_timeout(DEADLOCK_BOUND)
        .expect("post-poisoning submission must fail fast");
    assert_eq!(resp2.error.expect("lane is down").code, codes::LANE_FAILED);
    let stats = server.shutdown();
    assert_eq!(stats.lane_panics, 1, "one pool panic poisons the lane exactly once");
    assert_eq!(stats.completed, 0);
}

#[test]
fn round_stall_trips_the_watchdog_without_stopping_service() {
    let model = quantized_tiny();
    // Every round sleeps 60 ms against a 15 ms watchdog: the watchdog must
    // alarm (diagnosing the stuck round) while the request still completes.
    let plan = FaultPlan::parse("5:round_stall=1.0,stall_ms=60").unwrap();
    let mut cfg = ServerConfig::default();
    cfg.fault = Some(Arc::new(plan));
    cfg.watchdog_ms = 15;
    let server = ServerHandle::spawn(model, cfg);
    let resp = server
        .submit(req(1, 4))
        .recv_timeout(DEADLOCK_BOUND)
        .expect("stalled rounds must still finish");
    assert!(resp.error.is_none());
    assert_eq!(resp.tokens.len(), 4);
    let stats = server.shutdown();
    assert!(
        stats.watchdog_stalls >= 1,
        "60 ms stalls against a 15 ms watchdog must alarm (got {})",
        stats.watchdog_stalls
    );
}

#[test]
fn mixed_fault_schedule_soak_terminates_cleanly() {
    let model = quantized_tiny();
    // Allocation failures and occasional stalls together, under deadline
    // pressure: requests may expire, but every one must terminate with a
    // known outcome and the server must drain without deadlock.
    let plan = FaultPlan::parse("99:kv_alloc=0.25,round_stall=0.05,stall_ms=10").unwrap();
    let mut cfg = tight_paged_cfg(&model);
    cfg.fault = Some(Arc::new(plan));
    cfg.default_deadline_ms = 30_000;
    let server = ServerHandle::spawn(model, cfg);
    let want: Vec<usize> = (0..10).map(|i| 3 + (i % 5) * 2).collect();
    let rxs: Vec<_> =
        want.iter().enumerate().map(|(i, &n)| server.submit(req(i as u64, n))).collect();
    let mut completed = 0usize;
    let mut errored = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(DEADLOCK_BOUND)
            .unwrap_or_else(|_| panic!("request {i} never terminated under mixed faults"));
        assert_terminated(&resp, want[i]);
        if resp.error.is_none() {
            completed += 1;
        } else {
            errored += 1;
        }
    }
    assert_eq!(completed + errored, 10, "every request accounted for exactly once");
    let stats = server.shutdown();
    assert_eq!(stats.completed, completed, "stats must agree with observed completions");
}

#[test]
fn env_fault_schedule_soak() {
    // CI's chaos lane sets QTIP_FAULT to one schedule per matrix seed; the
    // server picks it up through `fault::global()` (cfg.fault = None). With
    // the variable unset this is a benign fault-free soak, so the test is
    // deterministic under plain `cargo test`.
    let injected = std::env::var("QTIP_FAULT").is_ok();
    let model = quantized_tiny();
    let mut cfg = tight_paged_cfg(&model);
    // Deadlines bound the soak even under hostile schedules (e.g. a high
    // kv_alloc rate that starves admission for a long time).
    cfg.default_deadline_ms = 30_000;
    cfg.watchdog_ms = 500;
    let server = ServerHandle::spawn(model, cfg);
    let want: Vec<usize> = (0..12).map(|i| 3 + (i % 4) * 3).collect();
    let rxs: Vec<_> =
        want.iter().enumerate().map(|(i, &n)| server.submit(req(i as u64, n))).collect();
    let mut completed = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(2 * DEADLOCK_BOUND)
            .unwrap_or_else(|_| panic!("request {i} never terminated (QTIP_FAULT set: {injected})"));
        assert_terminated(&resp, want[i]);
        if resp.error.is_none() {
            completed += 1;
        }
    }
    if !injected {
        assert_eq!(completed, 12, "fault-free soak must complete everything");
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, completed);
}
