//! The AOT bridge parity test: the Pallas-lowered HLO artifact executed through
//! PJRT must produce the same numbers as the native Rust fused decoder on the
//! same `QuantizedMatrix`. This is the proof that Layer 1/2 (Python, build time)
//! and Layer 3 (Rust, run time) implement one semantics.

use std::path::Path;

use qtip::quant::{quantize_matrix_qtip, QtipConfig};
use qtip::runtime::{PjrtRuntime, Registry};
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;

fn artifacts() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn quantize_for(rows: usize, cols: usize, code: &str, k: u32) -> qtip::quant::QuantizedMatrix {
    let mut rng = Rng::new(rows as u64 ^ k as u64);
    let w = Matrix::gaussian(rows, cols, 0.7, &mut rng);
    let h = Matrix::identity(cols);
    let cfg = QtipConfig {
        l: 16,
        k,
        v: 1,
        tx: 16,
        ty: 16,
        code: code.into(),
        seed: 0xA0_7E,
    };
    quantize_matrix_qtip(&w, &h, &cfg).qm
}

#[test]
fn pjrt_decode_matvec_matches_native() {
    let dir = artifacts();
    if !dir.join("aot_manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let reg = Registry::open(&dir).unwrap();
    let rt = PjrtRuntime::cpu().unwrap();

    for (rows, cols, code, k) in [
        (128usize, 128usize, "3inst", 2u32),
        (512, 128, "3inst", 2),
        (128, 512, "3inst", 2),
        (128, 128, "1mad", 2),
        (128, 128, "3inst", 4),
    ] {
        let info = reg
            .find_decode_matvec(rows, cols, code, k)
            .unwrap_or_else(|| panic!("missing artifact {code} {rows}x{cols} k{k}"));
        let exe = reg.load_decode_matvec(&rt, info).unwrap();
        let qm = quantize_for(rows, cols, code, k);

        let mut rng = Rng::new(7);
        let xt = rng.gauss_vec(cols);
        // Incoherent-space parity (the kernel's own contract).
        let mut y_native = vec![0.0f32; rows];
        qm.matvec_tilde(&xt, &mut y_native);
        let y_pjrt = exe.matvec_tilde(&qm, &xt).unwrap();
        let scale = y_native.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
        for (i, (a, b)) in y_pjrt.iter().zip(&y_native).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * scale,
                "{code} {rows}x{cols} k{k} row {i}: pjrt {a} native {b}"
            );
        }

        // Full original-space parity (RHT sandwich included).
        let x = rng.gauss_vec(cols);
        let y_full_native = qm.matvec(&x);
        let y_full_pjrt = exe.matvec(&qm, &x).unwrap();
        for (a, b) in y_full_pjrt.iter().zip(&y_full_native) {
            assert!((a - b).abs() < 1e-3 * scale);
        }
        eprintln!("parity OK: {code} {rows}x{cols} k{k}");
    }
}

#[test]
fn pjrt_dense_matvec_baseline_works() {
    let dir = artifacts();
    if !dir.join("aot_manifest.json").exists() {
        return;
    }
    let reg = Registry::open(&dir).unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let info = reg.find("matvec_f32_128x128").expect("dense artifact");
    let exe = rt.load_hlo(&info.path).unwrap();
    let mut rng = Rng::new(3);
    let w = Matrix::gaussian(128, 128, 1.0, &mut rng);
    let x = rng.gauss_vec(128);
    let expect = w.matvec(&x);
    let wl = xla::Literal::vec1(&w.data).reshape(&[128, 128]).unwrap();
    let xl = xla::Literal::vec1(&x);
    let got = PjrtRuntime::run_to_f32(&exe, &[wl, xl]).unwrap();
    for (a, b) in got.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-3);
    }
}

#[test]
fn pjrt_quantized_mlp_composes() {
    // The composed 3-projection MLP graph must execute and stay finite; its
    // structure (3 decode-matvecs + silu fused in one module) is the L2 demo.
    let dir = artifacts();
    if !dir.join("aot_manifest.json").exists() {
        return;
    }
    let reg = Registry::open(&dir).unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let info = reg.find("quantized_mlp_3inst_128_k2").expect("mlp artifact");
    let exe = rt.load_hlo(&info.path).unwrap();

    let gate = quantize_for(512, 128, "3inst", 2);
    let up = quantize_for(512, 128, "3inst", 2);
    let down = quantize_for(128, 512, "3inst", 2);
    let mut rng = Rng::new(9);
    let x = rng.gauss_vec(128);

    let lit = |qm: &qtip::quant::QuantizedMatrix| {
        xla::Literal::vec1(&qm.packed)
            .reshape(&[(qm.rows / 16) as i64, (qm.tile_words * qm.cols / 16) as i64])
            .unwrap()
    };
    let y = PjrtRuntime::run_to_f32(
        &exe,
        &[
            lit(&gate),
            lit(&up),
            lit(&down),
            xla::Literal::vec1(&x),
            xla::Literal::from(gate.scale),
            xla::Literal::from(up.scale),
            xla::Literal::from(down.scale),
        ],
    )
    .unwrap();
    assert_eq!(y.len(), 128);
    assert!(y.iter().all(|v| v.is_finite()));

    // Native reference of the same composition.
    let mut g = vec![0.0f32; 512];
    gate.matvec_tilde(&x, &mut g);
    let mut u = vec![0.0f32; 512];
    up.matvec_tilde(&x, &mut u);
    let h: Vec<f32> = g
        .iter()
        .zip(&u)
        .map(|(&gv, &uv)| gv / (1.0 + (-gv).exp()) * uv)
        .collect();
    let mut y_native = vec![0.0f32; 128];
    down.matvec_tilde(&h, &mut y_native);
    let scale = y_native.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for (a, b) in y.iter().zip(&y_native) {
        assert!((a - b).abs() < 1e-3 * scale, "{a} vs {b}");
    }
}
