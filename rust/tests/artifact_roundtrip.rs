//! Quantized-artifact persistence e2e: for every registered quant method, a
//! model saved with `io::save_quantized_model` and cold-start loaded again
//! must be **bit-identical** on the serving paths — per-layer
//! `matvec`/`matvec_multi` and full `decode_step` logits — and corrupted
//! artifacts must fail loudly.

use std::path::PathBuf;

use qtip::coordinator::quantize_model_qtip;
use qtip::hessian::collect_hessians;
use qtip::io::{load_quantized_model, save_quantized_model};
use qtip::model::{KvCache, Linear, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;

fn tiny_quantized(code: &str, v: u32, seed: u64) -> Transformer {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 1;
    cfg.max_seq = 32;
    cfg.name = "tiny".into();
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, seed));
    let seqs = vec![
        vec![1u16, 5, 9, 13, 17, 21, 25, 29],
        vec![2u16, 4, 8, 16, 32, 64, 128, 250],
    ];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig {
        l: 10,
        k: 2,
        v,
        tx: 8,
        ty: 8,
        code: code.into(),
        seed,
    };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    model
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("qtip_artifact_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn report_of(model: &Transformer) -> qtip::coordinator::QuantizeReport {
    // Reconstruct a minimal report from per-layer metrics (the real CLI keeps
    // the one quantize_model_qtip returned; tests only need a valid shape).
    let mut layers = Vec::new();
    let mut before = 0usize;
    let mut after = 0usize;
    for (name, lin) in model.linears() {
        let Linear::Quantized { qm, .. } = lin else { panic!("dense layer") };
        before += qm.rows * qm.cols * 4;
        after += qm.size_bytes();
        layers.push(qtip::coordinator::LayerReport {
            name,
            rows: qm.rows,
            cols: qm.cols,
            bytes_before: qm.rows * qm.cols * 4,
            bytes_after: qm.size_bytes(),
            metrics: qm.metrics,
        });
    }
    qtip::coordinator::QuantizeReport {
        layers,
        seconds: 0.0,
        bytes_before: before,
        bytes_after: after,
    }
}

#[test]
fn roundtrip_is_bit_identical_for_every_code_variant() {
    let dir = tmp_dir("codes");
    // Every registered method at its preferred V, plus lut's V=2 mode (the
    // one method whose V is configurable).
    let mut cases: Vec<(&str, u32)> =
        qtip::quant::registry::all().iter().map(|m| (m.name(), m.preferred_v())).collect();
    cases.push(("lut", 2));
    for (code, v) in cases {
        let tag = format!("{code}-v{v}");
        let model = tiny_quantized(code, v, 0xA5A5 + v as u64);
        let report = report_of(&model);
        save_quantized_model(&dir, &tag, &model, &report).unwrap();
        let (loaded, _rep, _info) = load_quantized_model(&dir, &tag).unwrap();

        // Per-layer serve kernels: single-column and batch-fused matvecs must
        // agree bit-for-bit with the freshly quantized model.
        let mut rng = Rng::new(7);
        for ((name, a), (_, b)) in model.linears().iter().zip(loaded.linears().iter()) {
            let x = rng.gauss_vec(a.cols());
            let ya = a.matvec(&x);
            let yb = b.matvec(&x);
            assert_eq!(ya, yb, "{tag}/{name}: matvec diverged after reload");

            let bsz = 3;
            let mut xm = Matrix::zeros(bsz, a.cols());
            for r in 0..bsz {
                let xr = rng.gauss_vec(a.cols());
                xm.row_mut(r).copy_from_slice(&xr);
            }
            let ma = a.matvec_multi(&xm);
            let mb = b.matvec_multi(&xm);
            assert_eq!(ma.data, mb.data, "{tag}/{name}: matvec_multi diverged after reload");
        }

        // Full decode path (the acceptance criterion: loaded-artifact logits
        // bit-identical to the in-process quantized model).
        let mut ca = KvCache::new(&model.cfg);
        let mut cb = KvCache::new(&loaded.cfg);
        for &t in &[0u16, 42, 101, 255, 7] {
            let la = model.decode_step(&mut ca, t);
            let lb = loaded.decode_step(&mut cb, t);
            assert_eq!(la, lb, "{tag}: decode_step logits diverged after reload");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_forward_matches_after_reload() {
    // The eval path (dense reconstruction caches) must also reproduce: the
    // caches are derived purely from artifact state.
    let dir = tmp_dir("batch");
    let mut model = tiny_quantized("3inst", 1, 99);
    let report = report_of(&model);
    save_quantized_model(&dir, "batch", &model, &report).unwrap();
    let (mut loaded, _rep, _info) = load_quantized_model(&dir, "batch").unwrap();
    model.ensure_caches();
    loaded.ensure_caches();
    let tokens = [3u16, 1, 4, 1, 5, 9, 2, 6];
    let a = model.forward_batch(&tokens);
    let b = loaded.forward_batch(&tokens);
    assert_eq!(a.data, b.data, "batch forward diverged after reload");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn damaged_artifacts_error_instead_of_panicking() {
    let dir = tmp_dir("damage");
    let model = tiny_quantized("3inst", 1, 5);
    let report = report_of(&model);
    save_quantized_model(&dir, "dmg", &model, &report).unwrap();

    // Truncation.
    let blob_path = dir.join("quant_dmg.bin");
    let blob = std::fs::read(&blob_path).unwrap();
    std::fs::write(&blob_path, &blob[..16]).unwrap();
    let err = load_quantized_model(&dir, "dmg").unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // Corruption at unchanged length.
    let mut bad = blob.clone();
    bad[7] ^= 0x01;
    std::fs::write(&blob_path, &bad).unwrap();
    let err = load_quantized_model(&dir, "dmg").unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");

    // Restore the blob but break the version (99 is above this build's
    // supported range; v1 artifacts still load via back-compat).
    std::fs::write(&blob_path, &blob).unwrap();
    let mpath = dir.join("quant_dmg.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    std::fs::write(&mpath, text.replace("\"format_version\":2", "\"format_version\":99"))
        .unwrap();
    let err = load_quantized_model(&dir, "dmg").unwrap_err().to_string();
    assert!(err.contains("format version 99"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
