//! Serving-stack integration: quantized model under the continuous batcher,
//! including mid-flight admission, stress over the paged KV arena,
//! preemption-by-eviction, contiguous-vs-paged scheduler parity, and the
//! overload behaviors (queue shedding, deadline expiry, slow-client
//! cancellation, panic-isolated lanes).

use std::sync::Arc;

use qtip::coordinator::{
    codes, quantize_model_qtip, GenRequest, ServerConfig, ServerHandle, StreamEvent,
};
use qtip::util::fault::FaultPlan;
use qtip::hessian::collect_hessians;
use qtip::model::{KvArena, KvCache, KvLayout, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;

fn quantized_tiny() -> Arc<Transformer> {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 96;
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 13));
    let seqs = vec![
        (0..64u16).collect::<Vec<_>>(),
        (100..164u16).collect::<Vec<_>>(),
    ];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 2 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    // NOTE: no ensure_caches() — the server path must work through the fused
    // decode matvec alone.
    Arc::new(model)
}

fn req(id: u64, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("req-{id}"),
        max_new_tokens: n,
        temperature: 0.0,
        top_k: 1,
        seed: id,
        model: String::new(),
        deadline_ms: 0,
    }
}

#[test]
fn serves_quantized_model_through_fused_decode() {
    let server = ServerHandle::spawn(quantized_tiny(), ServerConfig::default());
    let resp = server.submit(req(1, 12)).recv().unwrap();
    assert_eq!(resp.tokens.len(), 12);
    assert!(resp.decode_tok_per_sec > 0.0);
    let stats = server.shutdown();
    assert_eq!(stats.completed, 1);
}

#[test]
fn mid_flight_admission_preserves_outputs() {
    let model = quantized_tiny();
    // Run request A solo for reference.
    let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
    let ra = solo.submit(req(1, 20)).recv().unwrap();
    solo.shutdown();

    // Now start A, then inject B and C while A decodes.
    let server = ServerHandle::spawn(
        model,
        ServerConfig { max_batch: 4, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rx_a = server.submit(req(1, 20));
    std::thread::sleep(std::time::Duration::from_millis(5));
    let rx_b = server.submit(req(2, 8));
    let rx_c = server.submit(req(3, 8));
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    let c = rx_c.recv().unwrap();
    server.shutdown();
    assert_eq!(a.tokens, ra.tokens, "mid-flight admission corrupted request A");
    assert_eq!(b.tokens.len(), 8);
    assert_eq!(c.tokens.len(), 8);
}

#[test]
fn fused_batch_is_token_identical_across_heterogeneous_lengths() {
    // Requests with different prompt lengths and generation budgets decode in
    // the same fused rounds (heterogeneous KV cache lengths per round). Every
    // request must still be token-identical to running it alone, and the
    // batcher must actually have shared fused rounds between sequences.
    let model = quantized_tiny();
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest {
            id: i,
            prompt: "x".repeat(1 + 5 * i as usize),
            max_new_tokens: 5 + 3 * i as usize,
            temperature: 0.0,
            top_k: 1,
            seed: i,
            model: String::new(),
            deadline_ms: 0,
        })
        .collect();

    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig { max_batch: 4, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let batched: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let stats = server.shutdown();
    assert!(
        stats.max_fused_batch >= 2,
        "heterogeneous requests never shared a fused round (max fused batch {})",
        stats.max_fused_batch
    );

    for (r, b) in reqs.iter().zip(&batched) {
        assert_eq!(b.tokens.len(), r.max_new_tokens);
        assert_eq!(b.prompt_tokens, r.prompt.len());
        let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let alone = solo.submit(r.clone()).recv().unwrap();
        solo.shutdown();
        assert_eq!(
            alone.tokens, b.tokens,
            "request {} diverged between fused batch and solo decode",
            r.id
        );
    }
}

#[test]
fn stress_many_requests_small_pool() {
    let server = ServerHandle::spawn(
        quantized_tiny(),
        ServerConfig { max_batch: 3, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rxs: Vec<_> = (0..16).map(|i| server.submit(req(i, 4 + (i % 5) as usize))).collect();
    let mut seen = std::collections::BTreeSet::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.tokens.len(), 4 + (i % 5));
        seen.insert(r.id);
    }
    assert_eq!(seen.len(), 16, "every request answered exactly once");
    let stats = server.shutdown();
    assert_eq!(stats.completed, 16);
    assert!(stats.peak_batch <= 3);
    assert!(stats.queue_high_water >= 1, "16 requests through 3 slots must queue");
}

#[test]
fn paged_and_contig_schedulers_serve_identical_tokens_on_quantized_model() {
    // The paged arena walks block tables through the *quantized* fused decode
    // path; its tokens must match the contiguous reference scheduler exactly,
    // including with a tiny block size that forces mid-sequence boundaries.
    let model = quantized_tiny();
    let run = |layout: KvLayout, kv_block: usize| -> Vec<Vec<u16>> {
        let server = ServerHandle::spawn(
            model.clone(),
            ServerConfig { max_batch: 4, kv_layout: layout, kv_block, ..Default::default() },
        );
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                server.submit(GenRequest {
                    id: i,
                    prompt: "y".repeat(1 + 4 * i as usize),
                    max_new_tokens: 6 + 2 * i as usize,
                    temperature: 0.0,
                    top_k: 1,
                    seed: i,
                    model: String::new(),
                    deadline_ms: 0,
                })
            })
            .collect();
        let out = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
        server.shutdown();
        out
    };
    let reference = run(KvLayout::Contig, 0);
    for block in [3usize, 16] {
        assert_eq!(
            run(KvLayout::Paged, block),
            reference,
            "paged scheduler (block={block}) diverged on the quantized model"
        );
    }
}

#[test]
fn mixed_length_continuous_admission_preserves_streams_and_admits_more() {
    // Acceptance: mixed-length sequences admitted at different steps under a
    // tight budget — the paged scheduler must reach strictly higher
    // concurrency than sequence-granular admission AND keep every stream
    // token-identical to a solo run.
    let model = quantized_tiny();
    let per_seq = KvCache::size_bytes_for(&model.cfg);
    let budget = 2 * per_seq;
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| GenRequest {
            id: i,
            prompt: "p".repeat(1 + 3 * (i as usize % 3)),
            max_new_tokens: 24 + 4 * i as usize,
            temperature: 0.0,
            top_k: 1,
            seed: i,
            model: String::new(),
            deadline_ms: 0,
        })
        .collect();
    let run = |layout: KvLayout| {
        let server = ServerHandle::spawn(
            model.clone(),
            ServerConfig {
                max_batch: 6,
                kv_budget_bytes: budget,
                kv_layout: layout,
                ..Default::default()
            },
        );
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        let outs: Vec<Vec<u16>> = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
        (outs, server.shutdown())
    };
    let (contig_outs, contig) = run(KvLayout::Contig);
    let (paged_outs, paged) = run(KvLayout::Paged);
    assert_eq!(contig.completed, 6);
    assert_eq!(paged.completed, 6);
    assert!(contig.peak_active <= 2);
    assert!(
        paged.peak_active > contig.peak_active,
        "paged peak_active {} must exceed sequence-granular {}",
        paged.peak_active,
        contig.peak_active
    );
    assert_eq!(paged_outs, contig_outs, "scheduler choice changed the tokens");
    for (r, out) in reqs.iter().zip(&paged_outs) {
        let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let want = solo.submit(r.clone()).recv().unwrap();
        solo.shutdown();
        assert_eq!(&want.tokens, out, "request {} diverged from solo decode", r.id);
    }
}

#[test]
fn eviction_preemption_smoke_on_quantized_model() {
    // Block pressure on the quantized serving path: the youngest sequence is
    // evicted, re-queued, and restarted — outputs stay identical to solo
    // runs and every block returns to the arena (proven by a follow-up
    // request needing most of it).
    let model = quantized_tiny();
    let block = 8usize;
    let blocks = model.cfg.max_seq.div_ceil(block);
    let budget = blocks * KvArena::block_bytes(&model.cfg, block);
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 2,
            kv_budget_bytes: budget,
            kv_block: block,
            kv_layout: KvLayout::Paged,
            ..Default::default()
        },
    );
    let ra = req(1, 50);
    let rb = req(2, 50);
    let rx_a = server.submit(ra.clone());
    let rx_b = server.submit(rb.clone());
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    // Post-pressure health: a near-arena-sized request still completes.
    let c = server.submit(req(3, 60)).recv().unwrap();
    let stats = server.shutdown();
    assert_eq!(stats.completed, 3);
    assert!(stats.evictions >= 1, "two 50-token generations cannot share {blocks} blocks");
    assert_eq!(c.tokens.len(), 60);
    for (r, got) in [(ra, a), (rb, b)] {
        let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let want = solo.submit(r.clone()).recv().unwrap();
        solo.shutdown();
        assert_eq!(want.tokens, got.tokens, "request {} corrupted by eviction", r.id);
    }
}

#[test]
fn disconnect_mid_generation_does_not_hold_blocks() {
    // Satellite requirement: a client that vanishes mid-generation must have
    // its sequence cancelled and its blocks freed — proven by a follow-up
    // request that needs the whole arena.
    let model = quantized_tiny();
    let block = 8usize;
    let budget = model.cfg.max_seq.div_ceil(block) * KvArena::block_bytes(&model.cfg, block);
    let server = ServerHandle::spawn(
        model,
        ServerConfig {
            max_batch: 2,
            kv_budget_bytes: budget,
            kv_block: block,
            kv_layout: KvLayout::Paged,
            ..Default::default()
        },
    );
    let rx = server.submit_stream(req(1, 80));
    match rx.recv().unwrap() {
        StreamEvent::Token { .. } => {}
        ev => panic!("expected a first token, got {ev:?}"),
    }
    drop(rx); // client disconnects mid-generation
    let resp = server.submit(req(2, 80)).recv().unwrap();
    assert_eq!(resp.tokens.len(), 80);
    let stats = server.shutdown();
    assert_eq!(stats.cancelled, 1, "disconnected stream must be cancelled");
    assert_eq!(stats.completed, 1);
    assert!(
        stats.kv_blocks_high_water <= stats.kv_blocks_total,
        "arena accounting corrupted"
    );
}

#[test]
fn queue_full_sheds_immediately_with_structured_error() {
    // Bounded admission: with one decode slot and a one-deep queue, a third
    // concurrent request must be rejected at submission with `queue_full` —
    // not parked to wait out the backlog.
    let server = ServerHandle::spawn(
        quantized_tiny(),
        ServerConfig { max_batch: 1, max_queue: 1, ..Default::default() },
    );
    // Occupy the only slot; first token proves the request is active, so the
    // next two submissions land in (and then overflow) the waiting queue.
    let rx1 = server.submit_stream(req(1, 80));
    match rx1.recv().unwrap() {
        StreamEvent::Token { .. } => {}
        ev => panic!("expected a first token, got {ev:?}"),
    }
    let rx2 = server.submit(req(2, 4));
    let rx3 = server.submit(req(3, 4));
    let shed = rx3.recv().unwrap();
    let err = shed.error.expect("third request must be shed");
    assert_eq!(err.code, codes::QUEUE_FULL, "{err}");
    assert!(err.message.contains("queue is full"), "{err}");
    // Backpressure hint: queue_full sheds tell the client when to retry
    // (queue depth × recent round time, never zero).
    let hint = err.retry_after_ms.expect("queue_full must carry retry_after_ms");
    assert!(hint >= 1, "retry hint must be a positive number of ms, got {hint}");
    // The occupying and queued requests are unaffected by the shed.
    while let Ok(ev) = rx1.recv() {
        if matches!(ev, StreamEvent::Done(_)) {
            break;
        }
    }
    assert!(rx2.recv().unwrap().error.is_none());
    let stats = server.shutdown();
    assert_eq!(stats.shed_queue_full, 1);
    assert_eq!(stats.rejected, 0, "queue sheds are counted separately from rejections");
    assert_eq!(stats.completed, 2);
}

#[test]
fn queued_deadline_expires_with_structured_error_and_frees_the_slot() {
    // A request whose deadline lapses while it waits behind a long-running
    // decode must come back `deadline_exceeded` without ever occupying KV.
    let server = ServerHandle::spawn(
        quantized_tiny(),
        ServerConfig { max_batch: 1, ..Default::default() },
    );
    let rx1 = server.submit_stream(req(1, 80));
    match rx1.recv().unwrap() {
        StreamEvent::Token { .. } => {}
        ev => panic!("expected a first token, got {ev:?}"),
    }
    let mut hurried = req(2, 8);
    hurried.deadline_ms = 1;
    let rx2 = server.submit(hurried);
    let resp = rx2.recv().unwrap();
    let err = resp.error.expect("queued request must expire");
    assert_eq!(err.code, codes::DEADLINE_EXCEEDED, "{err}");
    assert!(err.message.contains("waiting in queue"), "{err}");
    // The server keeps serving after the expiry.
    while let Ok(ev) = rx1.recv() {
        if matches!(ev, StreamEvent::Done(_)) {
            break;
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.expired_queued, 1);
    assert_eq!(stats.expired_running, 0);
    assert_eq!(stats.completed, 1);
}

#[test]
fn slow_reader_is_cancelled_on_buffer_overflow_not_blocked_on() {
    // Slow-client backpressure: a streaming client that never drains its
    // bounded token buffer is cancelled when the buffer fills — the batcher
    // must never block on it, and other requests keep completing.
    let server = ServerHandle::spawn(
        quantized_tiny(),
        ServerConfig { max_batch: 2, stream_buffer: 4, ..Default::default() },
    );
    let rx_slow = server.submit_stream(req(1, 40));
    // Never read from rx_slow until the server has moved on: a healthy unary
    // request behind it must complete normally.
    let fast = server.submit(req(2, 8)).recv().unwrap();
    assert!(fast.error.is_none());
    assert_eq!(fast.tokens.len(), 8);
    let stats = server.shutdown();
    assert_eq!(stats.shed_slow_clients, 1, "overflowing stream must be shed");
    assert!(stats.cancelled >= 1, "slow-client sheds count as cancellations");
    assert_eq!(stats.completed, 1);
    // The abandoned receiver sees at most the buffered tokens, then
    // disconnect — never a Done event (RST-like termination).
    let mut tokens = 0;
    while let Ok(ev) = rx_slow.recv() {
        match ev {
            StreamEvent::Token { .. } => tokens += 1,
            StreamEvent::Done(_) => panic!("cancelled slow stream must not see Done"),
        }
    }
    assert!(tokens <= 4, "at most stream_buffer tokens were ever buffered, got {tokens}");
}

#[test]
fn lane_panic_is_isolated_and_health_degrades() {
    // Panic isolation: an injected decode panic in lane "beta" fails beta's
    // in-flight request with a structured error, marks the lane unhealthy,
    // and leaves lane "alpha" serving normally.
    let plan = FaultPlan::parse("7:decode_panic@beta=1.0").unwrap();
    let mut cfg = ServerConfig::default();
    cfg.fault = Some(Arc::new(plan));
    let server = ServerHandle::spawn_multi(
        vec![
            ("alpha".to_string(), quantized_tiny()),
            ("beta".to_string(), quantized_tiny()),
        ],
        cfg,
    );
    let mut to_beta = req(1, 8);
    to_beta.model = "beta".to_string();
    let resp = server.submit(to_beta).recv().unwrap();
    let err = resp.error.expect("beta's first round panics; its request must fail");
    assert_eq!(err.code, codes::LANE_FAILED, "{err}");

    // Alpha is untouched by beta's poisoning.
    let mut to_alpha = req(2, 8);
    to_alpha.model = "alpha".to_string();
    let ok = server.submit(to_alpha).recv().unwrap();
    assert!(ok.error.is_none(), "{:?}", ok.error);
    assert_eq!(ok.tokens.len(), 8);

    // Health reflects the partial failure: degraded, not dead.
    let health = server.health().expect("serving thread must answer the probe");
    assert!(health.degraded());
    assert!(!health.all_failed());
    for lane in &health.lanes {
        assert_eq!(lane.healthy, lane.name == "alpha", "lane {}", lane.name);
    }

    // New submissions to the poisoned lane are rejected immediately.
    let mut again = req(3, 8);
    again.model = "beta".to_string();
    let rejected = server.submit(again).recv().unwrap();
    assert_eq!(rejected.error.expect("poisoned lane rejects").code, codes::LANE_FAILED);

    let stats = server.shutdown();
    assert_eq!(stats.lane_panics, 1);
    assert_eq!(stats.completed, 1);
}
