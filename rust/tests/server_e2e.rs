//! Serving-stack integration: quantized model under the continuous batcher,
//! including mid-flight admission and stress over the KV pool.

use std::sync::Arc;

use qtip::coordinator::{
    quantize_model_qtip, GenRequest, ServerConfig, ServerHandle,
};
use qtip::hessian::collect_hessians;
use qtip::model::{ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;

fn quantized_tiny() -> Arc<Transformer> {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.d_ff = 64;
    cfg.n_layers = 2;
    cfg.max_seq = 96;
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 13));
    let seqs = vec![
        (0..64u16).collect::<Vec<_>>(),
        (100..164u16).collect::<Vec<_>>(),
    ];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 2 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {});
    // NOTE: no ensure_caches() — the server path must work through the fused
    // decode matvec alone.
    Arc::new(model)
}

fn req(id: u64, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("req-{id}"),
        max_new_tokens: n,
        temperature: 0.0,
        top_k: 1,
        seed: id,
    }
}

#[test]
fn serves_quantized_model_through_fused_decode() {
    let server = ServerHandle::spawn(quantized_tiny(), ServerConfig::default());
    let resp = server.submit(req(1, 12)).recv().unwrap();
    assert_eq!(resp.tokens.len(), 12);
    assert!(resp.decode_tok_per_sec > 0.0);
    let stats = server.shutdown();
    assert_eq!(stats.completed, 1);
}

#[test]
fn mid_flight_admission_preserves_outputs() {
    let model = quantized_tiny();
    // Run request A solo for reference.
    let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
    let ra = solo.submit(req(1, 20)).recv().unwrap();
    solo.shutdown();

    // Now start A, then inject B and C while A decodes.
    let server = ServerHandle::spawn(
        model,
        ServerConfig { max_batch: 4, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rx_a = server.submit(req(1, 20));
    std::thread::sleep(std::time::Duration::from_millis(5));
    let rx_b = server.submit(req(2, 8));
    let rx_c = server.submit(req(3, 8));
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    let c = rx_c.recv().unwrap();
    server.shutdown();
    assert_eq!(a.tokens, ra.tokens, "mid-flight admission corrupted request A");
    assert_eq!(b.tokens.len(), 8);
    assert_eq!(c.tokens.len(), 8);
}

#[test]
fn fused_batch_is_token_identical_across_heterogeneous_lengths() {
    // Requests with different prompt lengths and generation budgets decode in
    // the same fused rounds (heterogeneous KV cache lengths per round). Every
    // request must still be token-identical to running it alone, and the
    // batcher must actually have shared fused rounds between sequences.
    let model = quantized_tiny();
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest {
            id: i,
            prompt: "x".repeat(1 + 5 * i as usize),
            max_new_tokens: 5 + 3 * i as usize,
            temperature: 0.0,
            top_k: 1,
            seed: i,
        })
        .collect();

    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig { max_batch: 4, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let batched: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let stats = server.shutdown();
    assert!(
        stats.max_fused_batch >= 2,
        "heterogeneous requests never shared a fused round (max fused batch {})",
        stats.max_fused_batch
    );

    for (r, b) in reqs.iter().zip(&batched) {
        assert_eq!(b.tokens.len(), r.max_new_tokens);
        assert_eq!(b.prompt_tokens, r.prompt.len());
        let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let alone = solo.submit(r.clone()).recv().unwrap();
        solo.shutdown();
        assert_eq!(
            alone.tokens, b.tokens,
            "request {} diverged between fused batch and solo decode",
            r.id
        );
    }
}

#[test]
fn stress_many_requests_small_pool() {
    let server = ServerHandle::spawn(
        quantized_tiny(),
        ServerConfig { max_batch: 3, kv_budget_bytes: 1 << 30, ..Default::default() },
    );
    let rxs: Vec<_> = (0..16).map(|i| server.submit(req(i, 4 + (i % 5) as usize))).collect();
    let mut seen = std::collections::BTreeSet::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.tokens.len(), 4 + (i % 5));
        seen.insert(r.id);
    }
    assert_eq!(seen.len(), 16, "every request answered exactly once");
    let stats = server.shutdown();
    assert_eq!(stats.completed, 16);
    assert!(stats.peak_batch <= 3);
}
