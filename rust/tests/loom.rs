//! Loom lane: exhaustive model checking of the crate's two concurrency
//! protocols — the [`ExecPool`] dispatch/steal/park protocol and the
//! [`KvArena`] lease/release partition under external synchronization.
//!
//! This file only compiles under `RUSTFLAGS="--cfg loom"`, which switches
//! `qtip::util::sync` from `std::sync` re-exports to the (vendored) loom
//! doubles. `loom::model` then re-runs each closure under **every** thread
//! interleaving up to the `LOOM_MAX_PREEMPTIONS` bound (default 2), so the
//! assertions below hold for every schedule the model can produce, not just
//! the ones the CI machine happens to exhibit. Run locally with:
//!
//! ```text
//! cd rust && RUSTFLAGS="--cfg loom" cargo test --release --test loom -- --test-threads=1
//! ```
//!
//! Models are deliberately minimal (width-2 pools, 1–2 item jobs, 1-block
//! arenas): loom cost is exponential in visible operations, and the protocol
//! logic — busy-gate handoff, epoch observation, countdown-then-park,
//! lease/retain/release refcounting — is fully exercised by the smallest instance
//! with real concurrency. Observer counters use plain `std` atomics so they
//! do not add decision points to the explored schedule.

#![cfg(loom)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc as StdArc;

use qtip::model::{KvArena, KvSeq, ModelConfig};
use qtip::util::threadpool::ExecPool;

fn tiny_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 4;
    cfg.n_layers = 1;
    cfg.max_seq = 16;
    cfg
}

/// Every index of a dispatched job is executed exactly once, whether it is
/// claimed by the parked worker or stolen by the submitting thread, for every
/// interleaving of submit, worker wake-up, claim, countdown, and park.
#[test]
fn pool_run_executes_each_index_exactly_once() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        let hits: StdArc<Vec<AtomicUsize>> =
            StdArc::new((0..2).map(|_| AtomicUsize::new(0)).collect());
        let h2 = StdArc::clone(&hits);
        pool.run(2, move |i| {
            h2[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} claim count");
        }
        // Pool drop (shutdown flag, notify, join) is part of the model too.
    });
}

/// The pool survives consecutive submissions: the busy-gate release and the
/// `remaining` countdown of job 1 must hand the pool back in a state where
/// job 2 dispatches correctly under every schedule (a stale worker waking
/// late for job 1 must claim nothing from job 2's counter).
#[test]
fn pool_is_reusable_after_a_job_drains() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        let count = StdArc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let c = StdArc::clone(&count);
            pool.run(2, move |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 4);
    });
}

/// A panicking job index must surface as a panic from `run` on the submitter
/// — never a deadlock (the countdown still drains) — and must leave the pool
/// usable for the next submission, wherever the panicking index lands.
#[test]
fn pool_panic_propagates_and_pool_remains_usable() {
    // The panic fires in every explored schedule; silence the default hook so
    // the lane's log is not thousands of expected backtraces.
    std::panic::set_hook(Box::new(|_| {}));
    loom::model(|| {
        let pool = ExecPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, |i| {
                if i == 1 {
                    panic!("deliberate model panic");
                }
            });
        }));
        assert!(r.is_err(), "job panic must propagate out of run()");
        let ran = StdArc::new(AtomicUsize::new(0));
        let r2 = StdArc::clone(&ran);
        pool.run(2, move |_| {
            r2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 2, "pool must be reusable after a panic");
    });
    let _ = std::panic::take_hook();
}

/// `run` called from inside a job degrades to inline execution (the busy gate
/// is held by the outer job) instead of corrupting the outer dispatch —
/// whether the nested call happens on the submitter or on the worker.
#[test]
fn nested_run_degrades_to_inline_under_all_schedules() {
    loom::model(|| {
        let pool = StdArc::new(ExecPool::new(2));
        let inner = StdArc::new(AtomicUsize::new(0));
        let (p2, i2) = (StdArc::clone(&pool), StdArc::clone(&inner));
        pool.run(2, move |_| {
            let i3 = StdArc::clone(&i2);
            p2.run(2, move |_| {
                i3.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(inner.load(Ordering::SeqCst), 4);
    });
}

/// Two threads submitting to the same pool concurrently: exactly one wins the
/// busy gate (the other runs inline), and every index of both jobs executes
/// exactly once regardless of who wins.
#[test]
fn concurrent_submitters_never_corrupt_each_other() {
    loom::model(|| {
        let pool = StdArc::new(ExecPool::new(2));
        let count = StdArc::new(AtomicUsize::new(0));
        let (p2, c2) = (StdArc::clone(&pool), StdArc::clone(&count));
        let other = loom::thread::spawn(move || {
            let c3 = StdArc::clone(&c2);
            p2.run(2, move |_| {
                c3.fetch_add(1, Ordering::SeqCst);
            });
        });
        let c4 = StdArc::clone(&count);
        pool.run(2, move |_| {
            c4.fetch_add(1, Ordering::SeqCst);
        });
        other.join().unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 4, "both jobs must fully execute");
    });
}

/// Dropping a pool whose worker may not even have parked yet (or may be
/// mid-wake) always terminates: the shutdown flag, notify, and join handshake
/// has no lost-wakeup under any schedule.
#[test]
fn pool_drop_joins_worker_under_all_schedules() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        drop(pool);
    });
}

/// KvArena lease/release from two threads through a `util::sync` Mutex (the
/// serve loop's external synchronization, modeled): a successful exhaustive
/// `ensure` implies exclusive ownership of the pool's only block — verified
/// with the partition checker while the lock is held — and after both
/// threads release, the pool is whole again under every interleaving.
#[test]
fn kv_arena_lease_release_partition_under_interleaving() {
    loom::model(|| {
        let cfg = tiny_cfg();
        // One 8-position block total: the two threads contend for it.
        let arena = qtip::util::sync::Arc::new(qtip::util::sync::Mutex::new(KvArena::new(
            &cfg, 8, 1,
        )));
        let wins = StdArc::new(AtomicUsize::new(0));
        let (a2, w2) = (qtip::util::sync::Arc::clone(&arena), StdArc::clone(&wins));
        let worker = loom::thread::spawn(move || {
            let mut seq = KvSeq::new();
            let got = {
                let mut ar = a2.lock().unwrap();
                let got = ar.ensure(&mut seq, 8);
                if got {
                    // Holding the pool's only block means the partition over
                    // just our table must be exact.
                    ar.assert_partition([&seq]);
                }
                got
            };
            if got {
                w2.fetch_add(1, Ordering::SeqCst);
                let mut ar = a2.lock().unwrap();
                ar.release(&mut seq);
            }
        });
        let mut seq = KvSeq::new();
        let got = {
            let mut ar = arena.lock().unwrap();
            let got = ar.ensure(&mut seq, 8);
            if got {
                ar.assert_partition([&seq]);
            }
            got
        };
        if got {
            wins.fetch_add(1, Ordering::SeqCst);
            let mut ar = arena.lock().unwrap();
            ar.release(&mut seq);
        }
        worker.join().unwrap();
        // At least one thread must have won the block (both may, serially),
        // and after all releases the free list covers the pool exactly.
        assert!(wins.load(Ordering::SeqCst) >= 1, "the single block must be leasable");
        let ar = arena.lock().unwrap();
        assert_eq!(ar.blocks_free(), 1);
        ar.assert_partition(std::iter::empty());
    });
}

/// Concurrent retain/release of a shared block through the serve loop's
/// Mutex: the main thread leases the pool's only block, a second thread
/// aliases it onto its own table (refcount 2) and releases its alias, and
/// whichever order the release interleaves with the main thread's, free-on-
/// zero fires exactly once — at every lock point the partition
/// free ⊎ uniquely-leased ⊎ shared(rc ≥ 2) covers the pool exactly.
#[test]
fn kv_arena_shared_retain_release_partition_under_interleaving() {
    loom::model(|| {
        let cfg = tiny_cfg();
        let arena = qtip::util::sync::Arc::new(qtip::util::sync::Mutex::new(KvArena::new(
            &cfg, 8, 1,
        )));
        // Lease the only block before spawning, so the model explores the
        // retain/release orderings rather than acquire contention (covered by
        // the lease/release model above).
        let mut seq_a = KvSeq::new();
        let block = {
            let mut ar = arena.lock().unwrap();
            assert!(ar.ensure(&mut seq_a, 8), "empty pool must serve the first lease");
            seq_a.blocks()[0]
        };
        let a2 = qtip::util::sync::Arc::clone(&arena);
        let sharer = loom::thread::spawn(move || {
            let mut seq_b = KvSeq::new();
            {
                let mut ar = a2.lock().unwrap();
                ar.retain(&mut seq_b, block);
                assert_eq!(ar.refcount(block), 2, "alias must be visible under the lock");
                assert!(ar.is_shared(block));
                assert_eq!(ar.blocks_free(), 0);
            }
            let mut ar = a2.lock().unwrap();
            ar.release(&mut seq_b);
            assert!(
                ar.refcount(block) >= 1,
                "dropping the alias must never free the main thread's lease"
            );
        });
        {
            let ar = arena.lock().unwrap();
            // Whether the sharer has retained yet or not, our lease pins the
            // block: never free, refcount at least ours. (The full partition
            // check needs every table, so it waits for the join below.)
            assert!(ar.refcount(block) >= 1);
            assert_eq!(ar.blocks_free(), 0);
        }
        sharer.join().unwrap();
        let mut ar = arena.lock().unwrap();
        assert_eq!(ar.refcount(block), 1, "after the sharer exits only seq_a holds it");
        ar.assert_partition([&seq_a]);
        ar.release(&mut seq_a);
        assert_eq!(ar.blocks_free(), 1, "free-on-zero must fire exactly once");
        ar.assert_partition(std::iter::empty());
    });
}
