//! **Table 15/16 reproduction (shape)**: the "no speed constraint" quality
//! ceiling — a pure-lookup L=14 codebook with T_x=32, T_y=8 (smaller LDLQ group,
//! same 256 dimension), vs the fast HYB configuration and the VQ baseline.
//!
//! Shape to hold: LUT-L14 (quality ceiling) ≤ HYB ≤ E8P-VQ perplexity.

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::quant::BaselineKind;

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let eval_tokens = 256 * samples(4);
    let model = w.model();
    let hs = w.hessians(&model);
    let fp32 = w.fp32_ppl(eval_tokens);
    println!("fp32 ppl {fp32:.3}\n");

    let mut table = Table::new(
        "Table 15 — pure-LUT L=14 (Tx=32, Ty=8; 32KB codebook, future-hardware config)",
        &["bits", "LUT L=14 Tx=32 Ty=8", "HYB L=12 (fast)", "E8P-RVQ"],
    );

    for k in [4u32, 3, 2] {
        let mut lut_cfg = qtip_cfg("lut", 14, k, 1);
        lut_cfg.tx = 32;
        lut_cfg.ty = 8;
        let (pl, _) = w.qtip_ppl(&hs, &lut_cfg, eval_tokens);
        let mut hyb_cfg = qtip_cfg("hyb", 12, k, 2);
        hyb_cfg.seed = 0xB0B;
        let (ph, _) = w.qtip_ppl(&hs, &hyb_cfg, eval_tokens);
        let (pv, _) = w.baseline_ppl(
            &hs,
            &BaselineKind::E8Rvq { k, entries: 1 << 16 },
            eval_tokens,
        );
        table.row(vec![k.to_string(), f3(pl), f3(ph), f3(pv)]);
        println!("k={k}: lut14 {pl:.3} | hyb {ph:.3} | e8p {pv:.3}");
    }
    table.emit("table15_lut14.md");
}
