//! Cold-start latency: calibrate+quantize-from-scratch vs load-from-artifact,
//! measured down to the first decoded token — the payoff of the
//! quantize-once/serve-many workflow (`qtip quantize --save` →
//! `qtip serve --artifact`). Emits `bench_results/cold_start.md`.

use std::path::Path;

use qtip::bench::{f2, f3, Table};
use qtip::coordinator::quantize_model_qtip;
use qtip::hessian::collect_hessians;
use qtip::io::{load_quantized_model, save_quantized_model};
use qtip::model::{
    calibration_split, load_corpus, KvCache, ModelConfig, Transformer, WeightStore,
};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;
use qtip::util::Timer;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let name = "nano";
    let store = WeightStore::load(&dir, name)
        .unwrap_or_else(|_| WeightStore::random(&ModelConfig::by_name(name), 0x5EED));
    let corpus = {
        let holdout = dir.join("corpus_holdout.bin");
        if holdout.exists() {
            std::fs::read(&holdout).unwrap()
        } else {
            load_corpus(&[Path::new(env!("CARGO_MANIFEST_DIR"))], 1 << 20)
        }
    };

    // Path A: the full pipeline a server without artifacts must run.
    let t = Timer::start();
    let mut model = Transformer::from_store(&store);
    let seqs: Vec<Vec<u16>> = calibration_split(&corpus)
        .chunks(128)
        .take(24)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect();
    let hs = collect_hessians(&model, &seqs);
    let cfg = QtipConfig {
        l: 12,
        k: 2,
        v: 1,
        tx: 16,
        ty: 16,
        code: "3inst".into(),
        seed: 0x5171_50,
    };
    let report = quantize_model_qtip(&mut model, &hs, &cfg, &ExecPool::new(0), |_| {}).unwrap();
    let quant_model_secs = t.secs();
    let mut cache = KvCache::new(&model.cfg);
    let _ = model.decode_step(&mut cache, 42);
    let quant_first_tok = t.secs();

    // Persist once (temp dir; the CLI writes into artifacts/).
    let out = std::env::temp_dir().join(format!("qtip_cold_start_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    std::fs::create_dir_all(&out).unwrap();
    save_quantized_model(&out, "bench", &model, &report).unwrap();

    // Path B: cold-start from the saved artifact.
    let t = Timer::start();
    let (loaded, _rep, info) = load_quantized_model(&out, "bench").unwrap();
    let load_model_secs = t.secs();
    let mut cache = KvCache::new(&loaded.cfg);
    let _ = loaded.decode_step(&mut cache, 42);
    let load_first_tok = t.secs();

    let mut table = Table::new(
        "Cold start to first token: quantize-from-scratch vs artifact load (nano, 3INST L=12 k=2)",
        &["path", "secs to model", "secs to first token", "speedup"],
    );
    table.row(vec![
        "calibrate+quantize".into(),
        f3(quant_model_secs),
        f3(quant_first_tok),
        "1.00".into(),
    ]);
    table.row(vec![
        "artifact cold-start".into(),
        f3(load_model_secs),
        f3(load_first_tok),
        f2(quant_first_tok / load_first_tok.max(1e-9)),
    ]);
    println!("artifact blob: {} bytes ({})", info.blob_bytes, info.quant_desc);
    table.emit("cold_start.md");
    let _ = std::fs::remove_dir_all(&out);
}
