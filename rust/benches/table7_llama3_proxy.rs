//! **Tables 7/8/9 reproduction (shape)**: the "harder to quantize" regime.
//! Llama-3 is harder for Hessian-based rounding than Llama-2; we reproduce the
//! *mechanism* by evaluating the quantized model on a distribution-shifted
//! held-out set (JSON-structured synthetic text vs the source-code calibration
//! distribution), where rounding errors hurt more.
//!
//! Shape to hold: QTIP (TCQ) still orders strictly better than the VQ proxy at
//! every bitrate — the paper's point that the dimensionality advantage persists
//! on hard models.

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::coordinator::{quantize_model_baseline, quantize_model_qtip};
use qtip::eval::perplexity;
use qtip::quant::BaselineKind;
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;

/// Synthetic JSON-ish byte stream: structured, bracket-heavy, shifted from the
/// source-code training distribution.
fn shifted_corpus(bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let keys = ["id", "name", "value", "ts", "tags", "meta", "score"];
    let mut out = String::new();
    while out.len() < bytes {
        out.push('{');
        for i in 0..3 + rng.below(4) {
            if i > 0 {
                out.push(',');
            }
            let k = keys[rng.below(keys.len())];
            out.push_str(&format!("\"{k}\":"));
            if rng.below(2) == 0 {
                out.push_str(&format!("{}", rng.below(100000)));
            } else {
                out.push_str(&format!("\"v{}\"", rng.below(1000)));
            }
        }
        out.push_str("}\n");
    }
    out.into_bytes()
}

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let eval_tokens = 256 * samples(4);
    let shifted = shifted_corpus(64 << 10, 0x11A);
    let model = w.model();
    let hs = w.hessians(&model);

    let fp32_in = perplexity(&model, &w.eval, eval_tokens).ppl;
    let fp32_shift = perplexity(&model, &shifted, eval_tokens).ppl;
    println!("fp32: in-dist ppl {fp32_in:.3}, shifted ppl {fp32_shift:.3}\n");

    let mut table = Table::new(
        "Table 7 — hard (distribution-shifted) eval: QTIP vs VQ proxy",
        &["bits", "eval", "QTIP 3INST", "E8P-RVQ", "QTIP wins?"],
    );

    for k in [4u32, 3, 2] {
        let mut mq = w.model();
        let pool = ExecPool::sequential();
        quantize_model_qtip(&mut mq, &hs, &qtip_cfg("3inst", 12, k, 1), &pool, |_| {})
                .unwrap();
        mq.ensure_caches();
        let mut mv = w.model();
        quantize_model_baseline(
            &mut mv,
            &hs,
            &BaselineKind::E8Rvq { k, entries: 1 << 16 },
            1,
            &pool,
        )
        .unwrap();
        for (eval_name, data) in [("in-dist", w.eval.as_slice()), ("shifted", shifted.as_slice())] {
            let pq = perplexity(&mq, data, eval_tokens).ppl;
            let pv = perplexity(&mv, data, eval_tokens).ppl;
            table.row(vec![
                k.to_string(),
                eval_name.into(),
                f3(pq),
                f3(pv),
                if pq <= pv { "yes".into() } else { "NO".into() },
            ]);
            println!("k={k} {eval_name}: qtip {pq:.3} vs e8p {pv:.3}");
        }
    }
    table.emit("table7_llama3_proxy.md");
}
