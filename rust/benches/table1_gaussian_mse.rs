//! **Table 1 reproduction**: MSE of quantizing an i.i.d. N(0,1) source to 2 bits.
//!
//! Paper values: Lloyd-Max 0.118 | QuIP# E8P 0.089 | 1MAD 0.069 | 3INST 0.069 |
//! RPTC 0.068 | HYB 0.071 | 2D-RPTC 0.069 | D_R 0.063.
//! Shape to hold: SQ > VQ > TCQ, all TCQ variants within a few % of each other
//! and within ~15% of the distortion-rate bound.

use qtip::baselines::{E8Codebook, LloydMax};
use qtip::bench::{f4, samples, Table};
use qtip::codes::{Code, HybridCode, PureLutCode};
use qtip::trellis::{quantize_tail_biting, Trellis, Viterbi, ViterbiWorkspace};
use qtip::util::rng::Rng;
use qtip::util::stats::{gaussian_distortion_rate, mse};
use qtip::util::Timer;

fn tcq_mse(values: &[f32], l: u32, k: u32, v: u32, n_seqs: usize, t_len: usize) -> f64 {
    let trellis = Trellis::new(l, k, v);
    let vit = Viterbi::new(trellis, values);
    let mut rng = Rng::new(0x7AB1E1);
    let mut ws = ViterbiWorkspace::new();
    let mut total = 0.0;
    for _ in 0..n_seqs {
        let seq = rng.gauss_vec(t_len);
        let sol = quantize_tail_biting(&vit, &seq, &mut ws);
        total += mse(&vit.decode(&sol.states), &seq);
    }
    total / n_seqs as f64
}

fn main() {
    let k = 2u32;
    let t_len = 256;
    let n_seqs = samples(96);
    let n_scalar = n_seqs * t_len;
    println!("Table 1: {n_seqs} sequences of T={t_len}, k={k} bits/weight\n");
    let mut table = Table::new(
        "Table 1 — 2-bit quantization MSE on i.i.d. N(0,1) (paper values in parens)",
        &["Quantizer", "Dim", "MSE", "Paper", "secs"],
    );

    // --- SQ: Lloyd-Max ---
    let t = Timer::start();
    let lm = LloydMax::train(k, 400_000, 1);
    let mut rng = Rng::new(2);
    let xs = rng.gauss_vec(n_scalar);
    let lm_mse = mse(&lm.quantize_all(&xs), &xs);
    table.row(vec![
        "Lloyd-Max (SQ)".into(),
        "1".into(),
        f4(lm_mse),
        "0.118".into(),
        format!("{:.1}", t.secs()),
    ]);

    // --- VQ: E8P (2^16-entry E8 ball) ---
    let t = Timer::start();
    let e8 = E8Codebook::build(1 << 16, 3);
    let xs = rng.gauss_vec(n_scalar.min(8 * 4096));
    let e8_mse = mse(&e8.quantize_all(&xs), &xs);
    table.row(vec![
        "E8P ball VQ (QuIP# proxy)".into(),
        "8".into(),
        f4(e8_mse),
        "0.089".into(),
        format!("{:.1}", t.secs()),
    ]);

    // --- TCQ: computed codes, L=16 ---
    for (label, paper, values, v) in [
        ("QTIP 1MAD", "0.069", qtip::codes::build_code("1mad", 16, 1, 0).materialize(), 1u32),
        ("QTIP 3INST", "0.069", qtip::codes::build_code("3inst", 16, 1, 0).materialize(), 1),
        (
            "RPTC (pure-lookup LUT)",
            "0.068",
            PureLutCode::new(16, 1, 0xC0DE).table,
            1,
        ),
        (
            "QTIP HYB (V=2, Q=9)",
            "0.071",
            HybridCode::train_with(16, 2, 9, 0xB0B, 1 << 16, 40).materialize(),
            2,
        ),
        (
            "RPTC 2D (V=2 LUT)",
            "0.069",
            PureLutCode::new(16, 2, 0xC0DE2).table,
            2,
        ),
        (
            "HYB ARM (V=1, Q=6) §4.3",
            "~0.07",
            HybridCode::train_with(16, 1, 6, 0xA12, 1 << 15, 40).materialize(),
            1,
        ),
    ] {
        let t = Timer::start();
        let m = tcq_mse(&values, 16, k, v, n_seqs, t_len);
        table.row(vec![
            label.into(),
            "256".into(),
            f4(m),
            paper.into(),
            format!("{:.1}", t.secs()),
        ]);
    }

    table.row(vec![
        "D_R bound (infinite dim)".into(),
        "inf".into(),
        f4(gaussian_distortion_rate(k as f64)),
        "0.063".into(),
        "-".into(),
    ]);
    table.emit("table1_gaussian_mse.md");
}
