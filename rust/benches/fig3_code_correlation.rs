//! **Figure 3 reproduction**: correlation structure of neighboring trellis
//! windows under each code (L=16, k=2, V=1).
//!
//! Paper: the naive (monotone) code shows strong diagonal correlation; 1MAD has
//! minor structure; 3INST and a true random-Gaussian code are indistinguishable
//! from uncorrelated. We report the Pearson correlation over *all* representable
//! neighbor pairs and emit a scatter-sample CSV per code for plotting.

use qtip::bench::{f4, Table};
use qtip::codes::{build_code, Code};
use qtip::util::rng::Rng;
use qtip::util::stats::pearson;

fn neighbor_values(code: &dyn Code, l: u32, kv: u32) -> (Vec<f32>, Vec<f32>) {
    // All representable neighboring pairs: (state s, successor with new bits d).
    // Averaging over all d with s exhaustive = all edges of the trellis.
    let n = 1usize << l;
    let mut a = Vec::with_capacity(n * 2);
    let mut b = Vec::with_capacity(n * 2);
    let mut out = [0.0f32];
    let mut out2 = [0.0f32];
    let mut rng = Rng::new(0xF16);
    for s in 0..n as u32 {
        // Sample two successors per state (full fan-out would just duplicate).
        for _ in 0..2 {
            let d = (rng.next_u32()) & ((1 << kv) - 1);
            let next = (s >> kv) | (d << (l - kv));
            code.decode(s, &mut out);
            code.decode(next, &mut out2);
            a.push(out[0]);
            b.push(out2[0]);
        }
    }
    (a, b)
}

fn main() {
    let l = 16u32;
    let kv = 2u32;
    let mut table = Table::new(
        "Figure 3 — neighbor-window correlation, L=16 k=2 V=1 (|r|: corr >> 1MAD ≈ 3INST ≈ RPTC ≈ 0)",
        &["Code", "|Pearson r|", "paper panel"],
    );
    std::fs::create_dir_all("bench_results").ok();

    for (name, panel) in [
        ("corr", "far-left (strong correlations)"),
        ("1mad", "left-center (minor structure)"),
        ("3inst", "right-center (≈ random)"),
        ("lut", "far-right (random Gaussian)"),
    ] {
        let code = build_code(name, l, 1, 0xF3);
        let (a, b) = neighbor_values(code.as_ref(), l, kv);
        let r = pearson(&a, &b).abs();
        table.row(vec![name.into(), f4(r), panel.into()]);

        // Scatter sample for plotting (4096 points).
        let mut csv = String::from("prev,next\n");
        let step = (a.len() / 4096).max(1);
        for i in (0..a.len()).step_by(step) {
            csv.push_str(&format!("{},{}\n", a[i], b[i]));
        }
        std::fs::write(format!("bench_results/fig3_scatter_{name}.csv"), csv).ok();
    }
    table.emit("fig3_code_correlation.md");
    println!("scatter CSVs written to bench_results/fig3_scatter_<code>.csv");
}
