//! **Table 2 reproduction**: Algorithm 4's tail-biting approximation vs the
//! exact (overlap-enumerated) optimum on a (12, k, 1) trellis, k = 1..4.
//!
//! Paper: k=1: 0.2803 vs 0.2798 | k=2: 0.0733 | k=3: 0.0198 | k=4: 0.0055 —
//! Alg. 4 within ≲1% of optimal everywhere.

use qtip::bench::{f4, samples, Table};
use qtip::codes::PureLutCode;
use qtip::trellis::{
    quantize_tail_biting, quantize_tail_biting_exact, Trellis, Viterbi, ViterbiWorkspace,
};
use qtip::util::rng::Rng;
use qtip::util::stats::mse;
use qtip::util::Timer;

fn main() {
    let t_len = 256;
    let n_approx = samples(256);
    // The exact solver enumerates 2^(12-k) overlaps per sequence — keep it small.
    let n_exact = (n_approx / 32).max(4);
    println!("Table 2: (12,k,1) trellis, T={t_len}; Alg.4 over {n_approx} seqs, exact over {n_exact}\n");

    let mut table = Table::new(
        "Table 2 — tail-biting: Algorithm 4 vs optimal (paper: Alg4≈Opt to <1%)",
        &["k", "Alg.4 MSE", "Optimal MSE", "gap %", "paper Alg.4", "secs"],
    );
    let paper = ["0.2803", "0.0733", "0.0198", "0.0055"];

    for k in 1u32..=4 {
        let t = Timer::start();
        let trellis = Trellis::new(12, k, 1);
        let code = PureLutCode::new(12, 1, 0x7B + k as u64);
        let vit = Viterbi::new(trellis, &code.table);
        let mut ws = ViterbiWorkspace::new();

        // Alg. 4 on the large sample.
        let mut rng = Rng::new(100 + k as u64);
        let mut approx_total = 0.0;
        for _ in 0..n_approx {
            let seq = rng.gauss_vec(t_len);
            let sol = quantize_tail_biting(&vit, &seq, &mut ws);
            approx_total += mse(&vit.decode(&sol.states), &seq);
        }
        let approx_mse = approx_total / n_approx as f64;

        // Exact vs Alg.4 on the shared small sample (paired comparison).
        let mut rng = Rng::new(200 + k as u64);
        let (mut exact_total, mut approx_paired) = (0.0, 0.0);
        for _ in 0..n_exact {
            let seq = rng.gauss_vec(t_len);
            let ex = quantize_tail_biting_exact(&vit, &seq, &mut ws);
            let ap = quantize_tail_biting(&vit, &seq, &mut ws);
            assert!(ap.cost >= ex.cost - 1e-6, "exact must lower-bound Alg.4");
            exact_total += mse(&vit.decode(&ex.states), &seq);
            approx_paired += mse(&vit.decode(&ap.states), &seq);
        }
        let exact_mse = exact_total / n_exact as f64;
        let paired_mse = approx_paired / n_exact as f64;
        let gap = 100.0 * (paired_mse - exact_mse) / exact_mse;

        table.row(vec![
            k.to_string(),
            f4(approx_mse),
            f4(exact_mse),
            format!("{gap:.2}"),
            paper[(k - 1) as usize].into(),
            format!("{:.1}", t.secs()),
        ]);
    }
    table.emit("table2_tailbiting.md");
}
