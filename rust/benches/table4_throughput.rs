//! **Table 4 / Table 17 reproduction**: batch-1 decode throughput.
//!
//! Paper (RTX6000 Ada, 2-7B): FP16 55.9 tok/s | AQLM-2bit 81.5 | QuIP# 186 |
//! QTIP-2bit 188 | 3bit 161 | 4bit 140. Shape to hold on CPU DRAM roofline:
//! compressed >> fp32 at large sizes (matvec is memory-bound), cache-resident
//! computed codes >> cache-busting big-codebook VQ, and 2 > 3 > 4 bit ordering.
//! Table 17's device sweep becomes a matrix-size sweep (the memory-bound ratio
//! grows as the working set leaves cache).
//!
//! The second table is the serving-batch sweep (see `EXPERIMENTS.md` §Perf):
//! the batch-fused `matvec_tilde_multi` decodes each trellis state once per
//! round for all B activation columns, versus B independent `matvec_tilde`
//! passes that re-decode the packed stream per sequence. Shape to hold: fused
//! token throughput grows with B (decode amortizes) while per-sequence
//! throughput stays flat, so fused beats B× per-sequence by B = 8.

use qtip::bench::{f2, samples, BenchJson, Table};
use qtip::quant::{registry, QuantizedMatrix};
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;
use qtip::util::Timer;

/// Time y = Wx matvecs; returns (matvecs/s, GB/s effective on the weight bytes).
fn bench_matvec<F: FnMut(&[f32], &mut [f32])>(
    rows: usize,
    cols: usize,
    weight_bytes: usize,
    min_secs: f64,
    mut f: F,
) -> (f64, f64) {
    let mut rng = Rng::new(1);
    let x = rng.gauss_vec(cols);
    let mut y = vec![0.0f32; rows];
    f(&x, &mut y); // warmup
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < min_secs {
        f(&x, &mut y);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    (1.0 / per, weight_bytes as f64 / per / 1e9)
}

/// An AQLM-shape comparator: 8D VQ with a 1 MiB codebook — every group of 8
/// weights gathers a random row from a table too large for L1/L2 locality.
struct BigCodebookVq {
    codebook: Vec<f32>, // 2^16 x 8
    indices: Vec<u16>,  // rows*cols/8
    rows: usize,
    cols: usize,
}

impl BigCodebookVq {
    fn new(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let codebook = rng.gauss_vec(65536 * 8);
        let indices = (0..rows * cols / 8).map(|_| rng.next_u32() as u16).collect();
        BigCodebookVq { codebook, indices, rows, cols }
    }

    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let groups_per_row = self.cols / 8;
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for g in 0..groups_per_row {
                let idx = self.indices[r * groups_per_row + g] as usize;
                let cb = &self.codebook[idx * 8..idx * 8 + 8];
                let xs = &x[g * 8..g * 8 + 8];
                for i in 0..8 {
                    acc += cb[i] * xs[i];
                }
            }
            y[r] += acc;
        }
    }

    fn bytes(&self) -> usize {
        self.indices.len() * 2 + self.codebook.len() * 4
    }
}

fn main() {
    let min_secs = 0.3 * samples(1) as f64;
    let mut json = BenchJson::new("table4");
    let mut table = Table::new(
        "Table 4 / 17 — batch-1 decode-matvec throughput (shape: compressed ≥ fp32, computed codes ≥ big-codebook VQ, 2>3>4 bit)",
        &["d (square)", "Method", "bits", "matvec/s", "eff GB/s", "vs fp32"],
    );

    for d in [512usize, 1024, 2048, 4096] {
        let mut rng = Rng::new(d as u64);
        // fp32 baseline.
        let w = Matrix::gaussian(d, d, 0.3, &mut rng);
        let (fp_rate, fp_bw) =
            bench_matvec(d, d, d * d * 4, min_secs, |x, y| qtip::util::matrix::gemv(&w, x, y));
        table.row(vec![
            d.to_string(),
            "FP32 GEMV".into(),
            "32".into(),
            f2(fp_rate),
            f2(fp_bw),
            "1.00".into(),
        ]);
        let params = [
            ("d", d.to_string()),
            ("method", "fp32_gemv".to_string()),
            ("bits", "32".to_string()),
        ];
        json.row(&params, "matvec_per_sec", fp_rate);

        // AQLM-shape big-codebook VQ at ~2 bits.
        let vq = BigCodebookVq::new(d, d, 7);
        let (vq_rate, vq_bw) =
            bench_matvec(d, d, vq.bytes(), min_secs, |x, y| vq.matvec(x, y));
        table.row(vec![
            d.to_string(),
            "8D VQ, 1MiB codebook (AQLM shape)".into(),
            "2".into(),
            f2(vq_rate),
            f2(vq_bw),
            f2(vq_rate / fp_rate),
        ]);
        let params = [
            ("d", d.to_string()),
            ("method", "vq_big_codebook".to_string()),
            ("bits", "2".to_string()),
        ];
        json.row(&params, "matvec_per_sec", vq_rate);

        // QTIP computed codes at 2/3/4 bits.
        for k in [2u32, 3, 4] {
            let (trellis, spec) = registry::require("3inst").synthetic_entry(16, k, 3);
            let qm = QuantizedMatrix::synthetic(d, d, trellis, spec, 16, 16, 3);
            let bytes = qm.size_bytes();
            let (rate, bw) = bench_matvec(d, d, bytes, min_secs, |x, y| {
                y.fill(0.0);
                qm.matvec_tilde(x, y);
            });
            table.row(vec![
                d.to_string(),
                "QTIP 3INST (fused decode)".into(),
                k.to_string(),
                f2(rate),
                f2(bw),
                f2(rate / fp_rate),
            ]);
            let params = [
                ("d", d.to_string()),
                ("method", "qtip_3inst".to_string()),
                ("bits", k.to_string()),
            ];
            json.row(&params, "matvec_per_sec", rate);
        }

        // QTIP HYB (2-bit, V=2, Q=9 — 2KiB LUT stays L1-resident).
        let (trellis, spec) = registry::require("hyb").synthetic_entry(16, 2, 5);
        let qm = QuantizedMatrix::synthetic(d, d, trellis, spec, 16, 16, 4);
        let (rate, bw) = bench_matvec(d, d, qm.size_bytes(), min_secs, |x, y| {
            y.fill(0.0);
            qm.matvec_tilde(x, y);
        });
        table.row(vec![
            d.to_string(),
            "QTIP HYB (2KiB LUT)".into(),
            "2".into(),
            f2(rate),
            f2(bw),
            f2(rate / fp_rate),
        ]);
        let params = [
            ("d", d.to_string()),
            ("method", "qtip_hyb".to_string()),
            ("bits", "2".to_string()),
        ];
        json.row(&params, "matvec_per_sec", rate);
    }
    table.emit("table4_throughput.md");
    batch_sweep(min_secs, &mut json);
    thread_sweep(min_secs, &mut json);
    json.emit();
}

/// Intra-op scaling sweep: fused decode throughput as a batch × workers grid.
/// Shape to hold on a multi-core host: tok/s grows with worker count at every
/// batch size (tile bands parallelize the decode), and the batch-fusion gain
/// composes with the thread gain. On a single-core machine all worker counts
/// collapse to the width-1 row (outputs are bit-identical regardless).
fn thread_sweep(min_secs: f64, json: &mut BenchJson) {
    let mut table = Table::new(
        "Table 4 addendum — tile-parallel decode scaling (QTIP 3INST 2-bit, d=1024; \
         shape: tok/s grows with workers at every B; all cells bit-identical)",
        &["B", "workers", "rounds/s", "tok/s (cols/s)", "vs 1 worker"],
    );
    let d = 1024usize;
    let (trellis, spec) = registry::require("3inst").synthetic_entry(16, 2, 3);
    let qm = QuantizedMatrix::synthetic(d, d, trellis, spec, 16, 16, 3);
    let mut rng = Rng::new(13);

    for b in [1usize, 8] {
        let mut x = Matrix::zeros(b, d);
        for r in 0..b {
            let xr = rng.gauss_vec(d);
            x.row_mut(r).copy_from_slice(&xr);
        }
        let mut base_rate = 0.0f64;
        for workers in [1usize, 2, 4, 8] {
            let pool = ExecPool::new(workers);
            let mut y = Matrix::zeros(b, d);
            let mut xcol = Vec::new();
            let mut ys = vec![0.0f32; d];
            // Warmup (and the B=1 single-column path exercised explicitly).
            if b == 1 {
                ys.fill(0.0);
                qm.matvec_tilde_pool(x.row(0), &mut ys, &pool);
            } else {
                y.data.fill(0.0);
                qm.matvec_tilde_multi_pool(&x, &mut y, &mut xcol, &pool);
            }
            let t = Timer::start();
            let mut iters = 0usize;
            while t.secs() < min_secs {
                if b == 1 {
                    ys.fill(0.0);
                    qm.matvec_tilde_pool(x.row(0), &mut ys, &pool);
                } else {
                    y.data.fill(0.0);
                    qm.matvec_tilde_multi_pool(&x, &mut y, &mut xcol, &pool);
                }
                iters += 1;
            }
            let round_rate = iters as f64 / t.secs();
            let tok_rate = round_rate * b as f64;
            if workers == 1 {
                base_rate = tok_rate;
            }
            table.row(vec![
                b.to_string(),
                workers.to_string(),
                f2(round_rate),
                f2(tok_rate),
                f2(tok_rate / base_rate),
            ]);
            let params = [
                ("sweep", "threads".to_string()),
                ("b", b.to_string()),
                ("workers", workers.to_string()),
            ];
            json.row(&params, "tok_per_sec", tok_rate);
        }
    }
    table.emit("table4_thread_sweep.md");
}

/// Serving-batch sweep: one fused decode pass over B activation columns vs B
/// per-sequence passes (what the continuous batcher used to do per round).
fn batch_sweep(min_secs: f64, json: &mut BenchJson) {
    let mut table = Table::new(
        "Table 4 addendum — batch-fused decode matvec (QTIP 3INST 2-bit, d=1024; shape: fused tok/s grows with B, fused ≥ per-seq at B=8)",
        &["B", "path", "rounds/s", "tok/s (cols/s)", "fused vs per-seq"],
    );
    let d = 1024usize;
    let (trellis, spec) = registry::require("3inst").synthetic_entry(16, 2, 3);
    let qm = QuantizedMatrix::synthetic(d, d, trellis, spec, 16, 16, 3);
    let mut rng = Rng::new(11);

    for b in [1usize, 2, 4, 8] {
        let mut x = Matrix::zeros(b, d);
        for r in 0..b {
            let xr = rng.gauss_vec(d);
            x.row_mut(r).copy_from_slice(&xr);
        }
        let mut y = Matrix::zeros(b, d);

        // Per-sequence baseline: B independent fused matvecs — the packed
        // weight stream is decoded B times per round.
        let mut ys = vec![0.0f32; d];
        qm.matvec_tilde(x.row(0), &mut ys); // warmup
        let t = Timer::start();
        let mut iters = 0usize;
        while t.secs() < min_secs {
            for r in 0..b {
                ys.fill(0.0);
                qm.matvec_tilde(x.row(r), &mut ys);
            }
            iters += 1;
        }
        let seq_round_rate = iters as f64 / t.secs();
        let seq_tok_rate = seq_round_rate * b as f64;

        // Fused: one pass decodes each state once for all B columns.
        y.data.fill(0.0);
        qm.matvec_tilde_multi(&x, &mut y); // warmup
        let t = Timer::start();
        let mut iters = 0usize;
        while t.secs() < min_secs {
            y.data.fill(0.0);
            qm.matvec_tilde_multi(&x, &mut y);
            iters += 1;
        }
        let fused_round_rate = iters as f64 / t.secs();
        let fused_tok_rate = fused_round_rate * b as f64;

        table.row(vec![
            b.to_string(),
            format!("per-seq ×{b} matvec_tilde"),
            f2(seq_round_rate),
            f2(seq_tok_rate),
            "1.00".into(),
        ]);
        table.row(vec![
            b.to_string(),
            "fused matvec_tilde_multi".into(),
            f2(fused_round_rate),
            f2(fused_tok_rate),
            f2(fused_tok_rate / seq_tok_rate),
        ]);
        let params =
            [("sweep", "batch".to_string()), ("b", b.to_string()), ("path", "per_seq".to_string())];
        json.row(&params, "tok_per_sec", seq_tok_rate);
        let params =
            [("sweep", "batch".to_string()), ("b", b.to_string()), ("path", "fused".to_string())];
        json.row(&params, "tok_per_sec", fused_tok_rate);
    }
    table.emit("table4_batch_sweep.md");
}
