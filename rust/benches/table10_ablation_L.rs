//! **Table 10 reproduction**: ablation on trellis size L (k=2, V=1), pure-lookup
//! codebook vs the computed code.
//!
//! Paper: W2 ppl improves monotonically 8→10→12→16, and at L=16 the computed
//! 3INST code ("0 Kb of cache") matches the equal-geometry LUT — i.e. QTIP's
//! compute trick costs no quality. We also report the decoder table bytes that
//! motivate the whole exercise.

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::util::Timer;

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let eval_tokens = 256 * samples(4);
    let model = w.model();
    let hs = w.hessians(&model);
    let fp32 = w.fp32_ppl(eval_tokens);

    let mut table = Table::new(
        "Table 10 — ablation on L (k=2, V=1): quality ↑ with L; computed code ≈ LUT at equal L",
        &["codebook", "L", "decoder table bytes", "ppl", "secs"],
    );
    println!("fp32 ppl {fp32:.3}\n");

    for l in [8u32, 10, 12, 14] {
        let t = Timer::start();
        let (ppl, rep) = w.qtip_ppl(&hs, &qtip_cfg("lut", l, 2, 1), eval_tokens);
        let bytes = (1usize << l) * 2;
        table.row(vec![
            "LUT".into(),
            l.to_string(),
            bytes.to_string(),
            f3(ppl),
            format!("{:.0}", t.secs()),
        ]);
        println!("LUT L={l}: ppl {ppl:.3} ({:.0}s, {:.1}x)", t.secs(), rep.compression_ratio());
    }
    for l in [12u32, 14] {
        let t = Timer::start();
        let (ppl, _) = w.qtip_ppl(&hs, &qtip_cfg("3inst", l, 2, 1), eval_tokens);
        table.row(vec![
            "3INST (computed)".into(),
            l.to_string(),
            "0".into(),
            f3(ppl),
            format!("{:.0}", t.secs()),
        ]);
        println!("3INST L={l}: ppl {ppl:.3}");
    }
    // L=16 rows (the paper's headline geometry) — heavier; enabled by default,
    // drop QTIP_BENCH_SAMPLES to skip-by-time if needed.
    if samples(4) >= 4 {
        for code in ["lut", "3inst"] {
            let t = Timer::start();
            let (ppl, _) = w.qtip_ppl(&hs, &qtip_cfg(code, 16, 2, 1), eval_tokens);
            let bytes = if code == "lut" { (1usize << 16) * 2 } else { 0 };
            table.row(vec![
                format!("{code} @ L=16"),
                "16".into(),
                bytes.to_string(),
                f3(ppl),
                format!("{:.0}", t.secs()),
            ]);
            println!("{code} L=16: ppl {ppl:.3} ({:.0}s)", t.secs());
        }
    }
    table.emit("table10_ablation_L.md");
}
