//! **Tables 6 / 12 / 13 / 14 reproduction (shape)**: zeroshot-proxy accuracy
//! before/after quantization, per code.
//!
//! Shape to hold: 4-bit ≈ fp32 on every task; at 2 bits QTIP degrades less than
//! the scalar baseline (the paper's "QTIP matches or exceeds" claim).

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::coordinator::{quantize_model_baseline, quantize_model_qtip};
use qtip::eval::zeroshot_suite;
use qtip::quant::BaselineKind;
use qtip::util::threadpool::ExecPool;

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let cases = 16 * samples(2);
    let model = w.model();
    let hs = w.hessians(&model);

    let mut table = Table::new(
        "Table 6/12/13 — zeroshot-proxy accuracy (next-byte / copy / bracket)",
        &["method", "bits", "next-byte", "copy", "bracket", "mean"],
    );
    let zs = zeroshot_suite(&model, &w.eval, cases, 7);
    table.row(vec![
        "fp32".into(),
        "32".into(),
        f3(zs.next_byte_acc),
        f3(zs.copy_acc),
        f3(zs.bracket_acc),
        f3(zs.mean()),
    ]);

    for code in ["1mad", "3inst"] {
        for k in [4u32, 2] {
            let mut m = w.model();
            let pool = ExecPool::sequential();
            quantize_model_qtip(&mut m, &hs, &qtip_cfg(code, 12, k, 1), &pool, |_| {})
                .unwrap();
            m.ensure_caches();
            let z = zeroshot_suite(&m, &w.eval, cases, 7);
            table.row(vec![
                format!("QTIP {code}"),
                k.to_string(),
                f3(z.next_byte_acc),
                f3(z.copy_acc),
                f3(z.bracket_acc),
                f3(z.mean()),
            ]);
            println!("{code} k={k}: mean {:.3}", z.mean());
        }
    }
    for k in [4u32, 2] {
        let mut m = w.model();
        let pool = ExecPool::sequential();
        quantize_model_baseline(&mut m, &hs, &BaselineKind::Scalar { k }, 1, &pool).unwrap();
        let z = zeroshot_suite(&m, &w.eval, cases, 7);
        table.row(vec![
            "Scalar LDLQ".into(),
            k.to_string(),
            f3(z.next_byte_acc),
            f3(z.copy_acc),
            f3(z.bracket_acc),
            f3(z.mean()),
        ]);
    }
    table.emit("table6_zeroshot.md");
}
