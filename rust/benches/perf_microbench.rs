//! §Perf microbenchmarks: per-layer hot-path rates feeding EXPERIMENTS.md §Perf
//! and the machine-readable perf trajectory (`BENCH_microbench.json` with
//! `--json` / `QTIP_BENCH_JSON=1`).
//!  * code decode rate (weights/s) per code — the ALU cost the paper counts;
//!  * fused decode-matvec rate vs dense GEMV (bandwidth view);
//!  * scalar vs lane-blocked decode-matvec per code (§Perf optimization #2),
//!    single-thread — the lane speedup the acceptance gate tracks;
//!  * Viterbi quantization rate (state·steps/s) — encode-side throughput;
//!  * sgemm GF/s and RHT transforms/s (substrate rooflines).

use qtip::bench::{f2, samples, BenchJson, Table};
use qtip::codes::{build_code, Code};
use qtip::quant::{registry, KernelKind, QuantizedMatrix};
use qtip::trellis::{Trellis, Viterbi, ViterbiWorkspace};
use qtip::util::hadamard::hadamard_inplace;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::Timer;

fn main() {
    let scale = samples(1) as f64;
    let mut table = Table::new("§Perf microbenchmarks", &["kernel", "metric", "value"]);
    let mut json = BenchJson::new("microbench");

    // Decode rates, per registered method (each at its bench trellis width —
    // pure-LUT codes cap L so the table stays L1-resident).
    for m in registry::all() {
        let name = m.name();
        let l = m.bench_l();
        let (_trellis, spec) = m.synthetic_entry(l, 2, 1);
        let v = spec.v() as usize;
        let mask = (1u32 << l) - 1;
        let n = (4 << 20) as u32;
        let mut out = [0.0f32; 2];
        let t = Timer::start();
        let mut acc = 0.0f32;
        for s in 0..n {
            spec.decode(s & mask, &mut out[..v]);
            acc += out[0];
        }
        std::hint::black_box(acc);
        let rate = (n as f64 * v as f64) / t.secs() / 1e6;
        table.row(vec![
            format!("decode {name} (dyn-dispatch)"),
            "Mweights/s".into(),
            f2(rate),
        ]);
        json.row(&[("code", name.to_string())], "decode_mweights_per_sec", rate);
    }

    // Fused decode-matvec vs dense GEMV at d=2048.
    let d = 2048;
    let (ti_trellis, ti_spec) = registry::require("3inst").synthetic_entry(16, 2, 1);
    let qm = QuantizedMatrix::synthetic(d, d, ti_trellis, ti_spec, 16, 16, 2);
    let mut rng = Rng::new(3);
    let x = rng.gauss_vec(d);
    let mut y = vec![0.0f32; d];
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        y.fill(0.0);
        qm.matvec_tilde(&x, &mut y);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    let fused_rate = (d * d) as f64 / per / 1e6;
    table.row(vec![
        "fused decode-matvec 3inst 2048²".into(),
        "Mweights/s".into(),
        f2(fused_rate),
    ]);
    let fused_params = [
        ("code", "3inst".to_string()),
        ("d", d.to_string()),
        ("kernel", qm.kernel.name().to_string()),
    ];
    json.row(&fused_params, "fused_mweights_per_sec", fused_rate);

    let w = Matrix::gaussian(d, d, 0.1, &mut rng);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        qtip::util::matrix::gemv(&w, &x, &mut y);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    let gemv_gf = 2.0 * (d * d) as f64 / per / 1e9;
    table.row(vec!["dense GEMV 2048²".into(), "GF/s".into(), f2(gemv_gf)]);
    json.row(&[("d", d.to_string())], "gemv_gflops", gemv_gf);

    kernel_comparison(scale, &mut table, &mut json);

    // GEMM roofline.
    let a = Matrix::gaussian(256, 256, 1.0, &mut rng);
    let b = Matrix::gaussian(256, 256, 1.0, &mut rng);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        std::hint::black_box(a.matmul(&b));
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    let gemm_gf = 2.0 * 256f64.powi(3) / per / 1e9;
    table.row(vec!["sgemm 256³".into(), "GF/s".into(), f2(gemm_gf)]);
    json.row(&[("n", "256".to_string())], "sgemm_gflops", gemm_gf);

    // Viterbi encode rate.
    for l in [12u32, 16] {
        let trellis = Trellis::new(l, 2, 1);
        let code = build_code("3inst", l, 1, 1);
        let values = code.materialize();
        let vit = Viterbi::new(trellis, &values);
        let mut ws = ViterbiWorkspace::new();
        let seq = rng.gauss_vec(256);
        let t = Timer::start();
        let mut iters = 0;
        while t.secs() < 0.5 * scale {
            std::hint::black_box(vit.quantize(&seq, None, None, &mut ws));
            iters += 1;
        }
        let per = t.secs() / iters as f64;
        let states_steps = (1u64 << l) as f64 * 256.0;
        table.row(vec![
            format!("viterbi L={l} T=256"),
            "Mstate·step/s".into(),
            f2(states_steps / per / 1e6),
        ]);
        table.row(vec![
            format!("viterbi L={l} quantize rate"),
            "Kweights/s".into(),
            f2(256.0 / per / 1e3),
        ]);
        json.row(&[("l", l.to_string())], "viterbi_kweights_per_sec", 256.0 / per / 1e3);
    }

    // RHT.
    let mut buf = rng.gauss_vec(4096);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.3 * scale {
        hadamard_inplace(&mut buf);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    let fwht_rate = 4096.0 / per / 1e6;
    table.row(vec!["FWHT n=4096".into(), "Mel/s".into(), f2(fwht_rate)]);
    json.row(&[("n", "4096".to_string())], "fwht_mel_per_sec", fwht_rate);

    table.emit("perf_microbench.md");
    json.emit();
}

/// §Perf optimization #2: scalar vs lane-blocked fused decode-matvec,
/// single-thread, per registered quant method (each at its bench trellis
/// width). The acceptance gate tracks the 1MAD and 3INST `lanes_speedup`
/// rows (≥ 1.5× on the CI host); `ns_per_weight` is the trajectory metric
/// successive PRs compare. New registry entries get rows automatically.
fn kernel_comparison(scale: f64, table: &mut Table, json: &mut BenchJson) {
    let d = 1024usize;
    let specs: Vec<_> = registry::all()
        .iter()
        .map(|m| {
            let (trellis, spec) = m.synthetic_entry(m.bench_l(), 2, 5);
            (m.name(), trellis, spec)
        })
        .collect();
    let mut rng = Rng::new(41);
    let x = rng.gauss_vec(d);
    let mut y = vec![0.0f32; d];
    for (name, trellis, code) in specs {
        let mut qm = QuantizedMatrix::synthetic(d, d, trellis, code, 16, 16, 9);
        let mut rates = [0.0f64; 2];
        for (slot, kern) in [KernelKind::Scalar, KernelKind::Lanes].into_iter().enumerate() {
            qm.kernel = kern;
            y.fill(0.0);
            qm.matvec_tilde(&x, &mut y); // warmup
            let t = Timer::start();
            let mut iters = 0usize;
            while t.secs() < 0.3 * scale {
                y.fill(0.0);
                qm.matvec_tilde(&x, &mut y);
                iters += 1;
            }
            std::hint::black_box(&y);
            let per = t.secs() / iters as f64;
            let ns_per_weight = per * 1e9 / (d * d) as f64;
            rates[slot] = (d * d) as f64 / per;
            table.row(vec![
                format!("decode-matvec {name} {} 1024²", kern.name()),
                "ns/weight".into(),
                f2(ns_per_weight),
            ]);
            json.row(
                &[
                    ("code", name.to_string()),
                    ("kernel", kern.name().to_string()),
                    ("d", d.to_string()),
                ],
                "ns_per_weight",
                ns_per_weight,
            );
        }
        let speedup = rates[1] / rates[0];
        table.row(vec![
            format!("decode-matvec {name} lanes vs scalar"),
            "speedup".into(),
            f2(speedup),
        ]);
        json.row(&[("code", name.to_string()), ("d", d.to_string())], "lanes_speedup", speedup);
    }
}
