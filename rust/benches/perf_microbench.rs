//! §Perf microbenchmarks: per-layer hot-path rates feeding EXPERIMENTS.md §Perf.
//!  * code decode rate (weights/s) per code — the ALU cost the paper counts;
//!  * fused decode-matvec rate vs dense GEMV (bandwidth view);
//!  * Viterbi quantization rate (state·steps/s) — encode-side throughput;
//!  * sgemm GF/s and RHT transforms/s (substrate rooflines).

use qtip::bench::{f2, samples, Table};
use qtip::codes::{build_code, Code};
use qtip::quant::{CodeSpec, QuantizedMatrix};
use qtip::trellis::{Trellis, Viterbi, ViterbiWorkspace};
use qtip::util::hadamard::hadamard_inplace;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::Timer;

fn main() {
    let scale = samples(1) as f64;
    let mut table = Table::new("§Perf microbenchmarks", &["kernel", "metric", "value"]);

    // Decode rates.
    for name in ["1mad", "3inst", "hyb", "lut"] {
        let v = if name == "hyb" { 2 } else { 1 };
        let code = build_code(name, 16, v, 1);
        let n = (4 << 20) as u32;
        let mut out = [0.0f32; 2];
        let t = Timer::start();
        let mut acc = 0.0f32;
        for s in 0..n {
            code.decode(s & 0xFFFF, &mut out[..v as usize]);
            acc += out[0];
        }
        std::hint::black_box(acc);
        let rate = (n as f64 * v as f64) / t.secs() / 1e6;
        table.row(vec![
            format!("decode {name} (dyn-dispatch)"),
            "Mweights/s".into(),
            f2(rate),
        ]);
    }

    // Fused decode-matvec vs dense GEMV at d=2048.
    let d = 2048;
    let qm = QuantizedMatrix::synthetic(d, d, Trellis::new(16, 2, 1), CodeSpec::ThreeInst, 16, 16, 2);
    let mut rng = Rng::new(3);
    let x = rng.gauss_vec(d);
    let mut y = vec![0.0f32; d];
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        y.fill(0.0);
        qm.matvec_tilde(&x, &mut y);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    table.row(vec![
        "fused decode-matvec 3inst 2048²".into(),
        "Mweights/s".into(),
        f2((d * d) as f64 / per / 1e6),
    ]);

    let w = Matrix::gaussian(d, d, 0.1, &mut rng);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        qtip::util::matrix::gemv(&w, &x, &mut y);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    table.row(vec![
        "dense GEMV 2048²".into(),
        "GF/s".into(),
        f2(2.0 * (d * d) as f64 / per / 1e9),
    ]);

    // GEMM roofline.
    let a = Matrix::gaussian(256, 256, 1.0, &mut rng);
    let b = Matrix::gaussian(256, 256, 1.0, &mut rng);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.5 * scale {
        std::hint::black_box(a.matmul(&b));
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    table.row(vec![
        "sgemm 256³".into(),
        "GF/s".into(),
        f2(2.0 * 256f64.powi(3) / per / 1e9),
    ]);

    // Viterbi encode rate.
    for l in [12u32, 16] {
        let trellis = Trellis::new(l, 2, 1);
        let code = build_code("3inst", l, 1, 1);
        let values = code.materialize();
        let vit = Viterbi::new(trellis, &values);
        let mut ws = ViterbiWorkspace::new();
        let seq = rng.gauss_vec(256);
        let t = Timer::start();
        let mut iters = 0;
        while t.secs() < 0.5 * scale {
            std::hint::black_box(vit.quantize(&seq, None, None, &mut ws));
            iters += 1;
        }
        let per = t.secs() / iters as f64;
        let states_steps = (1u64 << l) as f64 * 256.0;
        table.row(vec![
            format!("viterbi L={l} T=256"),
            "Mstate·step/s".into(),
            f2(states_steps / per / 1e6),
        ]);
        table.row(vec![
            format!("viterbi L={l} quantize rate"),
            "Kweights/s".into(),
            f2(256.0 / per / 1e3),
        ]);
    }

    // RHT.
    let mut buf = rng.gauss_vec(4096);
    let t = Timer::start();
    let mut iters = 0;
    while t.secs() < 0.3 * scale {
        hadamard_inplace(&mut buf);
        iters += 1;
    }
    let per = t.secs() / iters as f64;
    table.row(vec![
        "FWHT n=4096".into(),
        "Mel/s".into(),
        f2(4096.0 / per / 1e6),
    ]);

    table.emit("perf_microbench.md");
}
