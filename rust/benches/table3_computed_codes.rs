//! **Table 3 reproduction (shape)**: pure-computed codes (1MAD/3INST, no
//! fine-tuning) vs the VQ comparator at 2/3/4 bits — held-out perplexity on the
//! trained nano model (the Llama substitute, DESIGN.md §4).
//!
//! Shape to hold: at every bitrate 1MAD/3INST ≤ E8P-VQ perplexity, and the gap
//! widens as bits decrease (the dimensionality advantage of TCQ).

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::quant::BaselineKind;

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let eval_tokens = 256 * samples(6);
    let model = w.model();
    let hs = w.hessians(&model);
    let fp32 = w.fp32_ppl(eval_tokens);

    let mut table = Table::new(
        "Table 3 — computed codes (no FT) vs VQ: held-out ppl on trained nano LM (fp32 baseline in caption)",
        &["bits", "QTIP 1MAD", "QTIP 3INST", "E8P-RVQ (QuIP# proxy)", "Scalar LDLQ (GPTQ proxy)"],
    );
    println!("fp32 baseline ppl: {fp32:.3} ({eval_tokens} eval tokens)\n");

    for k in [4u32, 3, 2] {
        let (p1, _) = w.qtip_ppl(&hs, &qtip_cfg("1mad", 12, k, 1), eval_tokens);
        let (p3, _) = w.qtip_ppl(&hs, &qtip_cfg("3inst", 12, k, 1), eval_tokens);
        let (pv, _) = w.baseline_ppl(
            &hs,
            &BaselineKind::E8Rvq { k, entries: 1 << 16 },
            eval_tokens,
        );
        let (ps, _) = w.baseline_ppl(&hs, &BaselineKind::Scalar { k }, eval_tokens);
        table.row(vec![k.to_string(), f3(p1), f3(p3), f3(pv), f3(ps)]);
        println!("k={k}: 1mad {p1:.3} | 3inst {p3:.3} | e8p {pv:.3} | scalar {ps:.3}");
    }
    table.emit("table3_computed_codes.md");
    println!("\n(fp32 = {fp32:.3}; paper shape: TCQ <= VQ <= scalar at every k, gap widest at k=2)");
}
