//! **Table 11 reproduction**: ablation on code vector dimension V at k=2.
//!
//! Paper: at L=12 quality degrades as V grows (1 → 2 → 4); a larger L recovers
//! it (L=16 V=2 ≈ L=12 V=1), and HYB matches the equal-geometry LUT.

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};

fn main() {
    let Some(w) = require_workload("nano", 16) else { return };
    let eval_tokens = 256 * samples(4);
    let model = w.model();
    let hs = w.hessians(&model);
    let fp32 = w.fp32_ppl(eval_tokens);
    println!("fp32 ppl {fp32:.3}\n");

    let mut table = Table::new(
        "Table 11 — ablation on V (k=2): quality ↓ with V at fixed L, recovered by larger L",
        &["codebook", "L", "V", "ppl"],
    );

    for (code, l, v) in [
        ("lut", 12u32, 1u32),
        ("lut", 12, 2),
        ("lut", 12, 4),
        ("lut", 14, 1),
        ("lut", 14, 2),
        ("hyb", 14, 2),
    ] {
        let mut cfg = qtip_cfg(code, l, 2, v);
        if code == "hyb" {
            cfg.seed = 0xB0B;
        }
        let (ppl, _) = w.qtip_ppl(&hs, &cfg, eval_tokens);
        table.row(vec![code.into(), l.to_string(), v.to_string(), f3(ppl)]);
        println!("{code} L={l} V={v}: ppl {ppl:.3}");
    }
    table.emit("table11_ablation_V.md");
}
