//! **Serving-scaling benchmark**: static (sequence-granular) round batching
//! vs the paged continuous batcher, under the *same* KV byte budget, on a
//! Zipf-ish mixed-length workload (a few long prompts, a long tail of short
//! ones — the shape real traffic has).
//!
//! Shape to hold: the paged scheduler admits strictly more concurrent
//! sequences (its admission unit is a block, not a full `max_seq` cache), so
//! aggregate tok/s rises with the extra fused-batch width while per-request
//! outputs stay bit-identical. The second table sweeps the arena geometry
//! (`--kv-block`): smaller blocks waste less tail capacity but pay more
//! block-table bookkeeping.
//!
//! The third table runs a **Zipf-shared-prefix** workload (a few hot
//! "personas" whose long system prompt dominates the token stream, each
//! request adding a short unique tail) with prefix sharing on vs off under
//! the same tight KV budget: sharing aliases the persona prefix's blocks
//! instead of re-prefilling them, so admitted concurrency rises and mean
//! TTFT falls while outputs stay bit-identical.
//!
//! The fourth table measures **overload shedding**: the same server shape
//! under a nominal load (fits the batch, nothing shed) and under a burst far
//! past a bounded admission queue (`max_queue`). Overload must shed loudly
//! (`queue_full` rejections, counted in `shed_queue_full`) while the
//! requests it *does* admit keep a mean TTFT within 2× of the nominal run —
//! load shedding protects latency instead of letting the backlog eat it.
//!
//! The fifth table runs a **long/short prompt mix** with chunked GEMM
//! prefill on (`--prefill-chunk 32`) vs off (token-at-a-time, chunk 1) at
//! the same KV budget: chunking decodes each quantized weight tile once per
//! chunk of prompt positions instead of once per token, so long-prompt mean
//! and p95 TTFT drop while decode throughput and the emitted tokens stay
//! unchanged. Tables 1–4 pin `prefill_chunk: 1` so their measurements keep
//! the pre-chunking semantics and the prefill effect is isolated to table 5.
//!
//! Emits `BENCH_serving.json` (schema v1) with `tok_per_sec`,
//! `peak_concurrency`, and `evictions` rows per scheduler plus
//! `peak_concurrency` / `mean_ttft_s` / `prefix_hits` rows per prefix mode,
//! `shed_queue_full` / `mean_ttft_s` / `completed` rows per overload
//! workload, and `long_mean_ttft_s` / `long_p95_ttft_s` /
//! `decode_tok_per_sec` / `prefill_chunks` rows per prefill mode for the
//! perf trajectory; `scripts/check_bench_json.py --require-paging-gain
//! --require-prefix-gain --require-shed-sanity --require-prefill-gain`
//! enforces the strictly-more-concurrency, shared-beats-unshared,
//! shed-under-overload-only, and chunked-prefill-TTFT acceptance gates in
//! CI.

use std::sync::Arc;

use qtip::bench::{f2, samples, BenchJson, Table};
use qtip::coordinator::{
    quantize_model_qtip, GenRequest, ServerConfig, ServerHandle, ServerStats,
};
use qtip::hessian::collect_hessians;
use qtip::model::{KvCache, KvLayout, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;
use qtip::util::Timer;

fn bench_model() -> Arc<Transformer> {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 2;
    cfg.max_seq = 128;
    cfg.name = "serving-bench".into();
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 0xBEEF));
    let seqs = vec![(0..96u16).collect::<Vec<_>>(), (50..146u16).collect::<Vec<_>>()];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 7 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    Arc::new(model)
}

/// Zipf-ish mixed-length workload: request r of rank k (cycling 1..=8) gets a
/// prompt of ~`60/k` tokens and a generation budget of ~`48/k` tokens — a few
/// heavy requests, a long tail of light ones.
fn workload(n: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            let rank = (i % 8) + 1;
            GenRequest {
                id: i as u64,
                prompt: "x".repeat((60 / rank).max(1)),
                max_new_tokens: (48 / rank).max(4),
                temperature: 0.0,
                top_k: 1,
                seed: i as u64,
                model: String::new(),
                deadline_ms: 0,
            }
        })
        .collect()
}

/// Zipf-shared-prefix workload: four "personas" with hit ratio 4:2:1:1, each
/// owning a 64-char system prompt; request `i` appends a short unique tail,
/// so the shared prefix covers whole KV blocks and divergence lands at a
/// block boundary. Deterministic (temperature 0) so prefix-on and prefix-off
/// runs produce identical tokens.
fn zipf_prefix_workload(n: usize) -> Vec<GenRequest> {
    let persona_prompt = |p: usize| {
        // 4 × 16 = 64 chars = 64 byte-tokens = whole blocks for block sizes
        // 4/8/16 — the shape a shared system prompt has.
        format!("[persona {p}] ").chars().cycle().take(64).collect::<String>()
    };
    (0..n)
        .map(|i| {
            // Zipf-ish persona popularity out of every 8 requests: persona 0
            // ×4, persona 1 ×2, personas 2 and 3 ×1.
            let persona = match i % 8 {
                0 | 2 | 4 | 6 => 0,
                1 | 5 => 1,
                3 => 2,
                _ => 3,
            };
            GenRequest {
                id: i as u64,
                prompt: format!("{}#u{:03}", persona_prompt(persona), i),
                max_new_tokens: 8,
                temperature: 0.0,
                top_k: 1,
                seed: i as u64,
                model: String::new(),
                deadline_ms: 0,
            }
        })
        .collect()
}

/// Run the whole workload through one server; returns (wall secs, stats,
/// mean TTFT secs).
fn run_workload(
    model: &Arc<Transformer>,
    layout: KvLayout,
    kv_block: usize,
    budget: usize,
    prefix_share: bool,
    reqs: &[GenRequest],
) -> (f64, ServerStats, f64) {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 16,
            kv_budget_bytes: budget,
            kv_layout: layout,
            kv_block,
            prefix_share,
            // Token-at-a-time: tables 1-3 predate chunked prefill and their
            // gates compare scheduler/geometry/prefix effects — table 5 owns
            // the chunking comparison.
            prefill_chunk: 1,
            ..Default::default()
        },
    );
    let t = Timer::start();
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut total_tokens = 0usize;
    let mut ttft_sum = 0.0f64;
    for rx in rxs {
        let r = rx.recv().expect("request served");
        assert!(r.error.is_none(), "bench request rejected: {:?}", r.error);
        total_tokens += r.tokens.len();
        ttft_sum += r.ttft;
    }
    let secs = t.secs();
    let stats = server.shutdown();
    assert_eq!(stats.completed, reqs.len());
    assert!(total_tokens > 0);
    (secs, stats, ttft_sum / reqs.len().max(1) as f64)
}

/// Long/short prompt mix for the chunked-prefill comparison: every fourth
/// request carries a 100-token prompt (dominated by prefill cost), the rest
/// a 12-token one; everyone generates 8 tokens at temperature 0 so the on
/// and off runs emit identical text and differ only in scheduling.
fn prefill_mix_workload(n: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            let long = i % 4 == 0;
            GenRequest {
                id: i as u64,
                prompt: "y".repeat(if long { 100 } else { 12 }),
                max_new_tokens: 8,
                temperature: 0.0,
                top_k: 1,
                seed: i as u64,
                model: String::new(),
                deadline_ms: 0,
            }
        })
        .collect()
}

/// Sorted-in-place p95 (ceil-rank convention; the max for small samples).
fn p95(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("TTFTs are finite"));
    let idx = ((xs.len() as f64) * 0.95).ceil() as usize;
    xs[idx.saturating_sub(1).min(xs.len() - 1)]
}

/// Run the prefill mix through a paged server with the given chunk geometry;
/// returns (stats, long-prompt TTFTs, short-prompt mean TTFT). `kv_block` is
/// left at 0 so the `QTIP_KV_BLOCK=4` CI variant exercises the chunk/block
/// interaction.
fn run_prefill_mix(
    model: &Arc<Transformer>,
    prefill_chunk: usize,
    budget: usize,
    reqs: &[GenRequest],
) -> (ServerStats, Vec<f64>, f64) {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 16,
            kv_budget_bytes: budget,
            kv_layout: KvLayout::Paged,
            kv_block: 0,
            prefill_chunk,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut long_ttfts = Vec::new();
    let mut short_sum = 0.0f64;
    let mut short_n = 0usize;
    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx.recv().expect("request served");
        assert!(r.error.is_none(), "prefill-mix request rejected: {:?}", r.error);
        if req.prompt.len() >= 64 {
            long_ttfts.push(r.ttft);
        } else {
            short_sum += r.ttft;
            short_n += 1;
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, reqs.len());
    (stats, long_ttfts, short_sum / short_n.max(1) as f64)
}

/// Overload-tolerant runner: `queue_full` sheds are expected (they are the
/// measurement), any other error still fails the bench. Returns the final
/// stats, the mean TTFT over the requests that were actually admitted and
/// completed, and the count the client saw shed.
fn run_shedding_workload(
    model: &Arc<Transformer>,
    max_queue: usize,
    reqs: &[GenRequest],
) -> (ServerStats, f64, usize) {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 12,
            kv_budget_bytes: 16 * KvCache::size_bytes_for(&model.cfg),
            kv_layout: KvLayout::Paged,
            kv_block: 16,
            prefill_chunk: 1,
            max_queue,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut done = 0usize;
    let mut shed = 0usize;
    let mut ttft_sum = 0.0f64;
    for rx in rxs {
        let r = rx.recv().expect("request answered");
        match &r.error {
            None => {
                done += 1;
                ttft_sum += r.ttft;
            }
            Some(err) => {
                assert_eq!(
                    err.code,
                    qtip::coordinator::codes::QUEUE_FULL,
                    "only queue sheds are acceptable under this workload: {err}"
                );
                shed += 1;
            }
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, done);
    assert_eq!(stats.shed_queue_full, shed, "client-observed sheds must match stats");
    (stats, ttft_sum / done.max(1) as f64, shed)
}

fn main() {
    let model = bench_model();
    let reps = samples(2);
    let n_requests = 24 * reps.max(1);
    let reqs = workload(n_requests);
    // Budget: two full contiguous caches — tight enough that sequence-
    // granular admission serializes the workload into pairs while the paged
    // arena runs a wide batch from the same bytes.
    let budget = 2 * KvCache::size_bytes_for(&model.cfg);

    let mut json = BenchJson::new("serving");
    let mut t1 = Table::new(
        "Serving: static (contig) vs continuous (paged) batching, same KV budget",
        &["scheduler", "wall s", "tok/s", "peak concurrency", "evictions", "kv high-water B"],
    );
    for (name, layout) in [("contig", KvLayout::Contig), ("paged", KvLayout::Paged)] {
        let (secs, stats, _) = run_workload(&model, layout, 0, budget, true, &reqs);
        t1.row(vec![
            name.into(),
            f2(secs),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.peak_active),
            format!("{}", stats.evictions),
            format!("{}", stats.peak_kv_bytes),
        ]);
        let params = [("scheduler", name.to_string())];
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "peak_concurrency", stats.peak_active as f64);
        json.row(&params, "evictions", stats.evictions as f64);
    }
    t1.emit("serving_scheduler.md");

    let mut t2 = Table::new(
        "Paged arena geometry sweep (--kv-block)",
        &["block positions", "blocks", "tok/s", "peak concurrency", "evictions"],
    );
    for block in [8usize, 32, 128] {
        let (_, stats, _) = run_workload(&model, KvLayout::Paged, block, budget, true, &reqs);
        t2.row(vec![
            format!("{block}"),
            format!("{}", stats.kv_blocks_total),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.peak_active),
            format!("{}", stats.evictions),
        ]);
        let params = [("kv_block", block.to_string())];
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "peak_concurrency", stats.peak_active as f64);
    }
    t2.emit("serving_geometry.md");

    // Zipf-shared-prefix workload: prefix sharing on vs off, paged arena,
    // block 8 (the 64-token persona prompt is exactly 8 whole blocks), under a
    // budget of three contiguous caches — tight enough that re-prefilling
    // every persona prompt caps admission, while aliasing it frees most of
    // each sequence's footprint.
    let zreqs = zipf_prefix_workload(n_requests);
    let zbudget = 3 * KvCache::size_bytes_for(&model.cfg);
    let mut t3 = Table::new(
        "Zipf-shared-prefix workload: prefix sharing on vs off, same KV budget",
        &[
            "prefix",
            "mean TTFT ms",
            "tok/s",
            "peak concurrency",
            "prefix hits",
            "blocks aliased",
            "cow copies",
        ],
    );
    for (mode, share) in [("off", false), ("on", true)] {
        let (_, stats, mean_ttft) =
            run_workload(&model, KvLayout::Paged, 8, zbudget, share, &zreqs);
        t3.row(vec![
            mode.into(),
            f2(mean_ttft * 1e3),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.peak_active),
            format!("{}", stats.prefix_hits),
            format!("{}", stats.blocks_shared),
            format!("{}", stats.cow_copies),
        ]);
        let params = [("workload", "zipf_prefix".to_string()), ("prefix", mode.to_string())];
        json.row(&params, "mean_ttft_s", mean_ttft);
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "peak_concurrency", stats.peak_active as f64);
        json.row(&params, "prefix_hits", stats.prefix_hits as f64);
        json.row(&params, "blocks_shared", stats.blocks_shared as f64);
        json.row(&params, "cow_copies", stats.cow_copies as f64);
    }
    t3.emit("serving_prefix.md");

    // Overload shedding: nominal load (12 short requests into a 12-wide
    // batch, unbounded queue) vs a 48-request burst against max_queue 2. The
    // burst must shed, the nominal run must not, and the requests the burst
    // admits must keep TTFT in the same regime as nominal.
    let short = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| GenRequest {
                id: i as u64,
                prompt: "o".repeat(12),
                max_new_tokens: 8,
                temperature: 0.0,
                top_k: 1,
                seed: i as u64,
                model: String::new(),
                deadline_ms: 0,
            })
            .collect()
    };
    let mut t4 = Table::new(
        "Overload shedding: nominal vs 4x burst against a bounded queue",
        &["workload", "requests", "completed", "shed (queue full)", "mean TTFT ms", "tok/s"],
    );
    for (name, max_queue, n) in [("nominal", 0usize, 12usize), ("overload", 2, 48)] {
        let (stats, mean_ttft, shed) = run_shedding_workload(&model, max_queue, &short(n));
        t4.row(vec![
            name.into(),
            format!("{n}"),
            format!("{}", stats.completed),
            format!("{shed}"),
            f2(mean_ttft * 1e3),
            f2(stats.throughput_tok_per_sec()),
        ]);
        let params = [("workload", name.to_string())];
        json.row(&params, "shed_queue_full", shed as f64);
        json.row(&params, "mean_ttft_s", mean_ttft);
        json.row(&params, "completed", stats.completed as f64);
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
    }
    t4.emit("serving_overload.md");

    // Chunked prefill on (32) vs off (1) on the long/short mix, same paged
    // server and KV budget; outputs are bit-identical so the comparison is
    // pure scheduling. Budget: eight contiguous caches — roomy enough that
    // capacity pressure does not confound the TTFT comparison.
    let preqs = prefill_mix_workload(n_requests);
    let pbudget = 8 * KvCache::size_bytes_for(&model.cfg);
    let mut t5 = Table::new(
        "Long/short prompt mix: chunked GEMM prefill on vs off, same KV budget",
        &[
            "chunked",
            "long mean TTFT ms",
            "long p95 TTFT ms",
            "short mean TTFT ms",
            "decode tok/s",
            "prefill chunks",
            "budget deferrals",
        ],
    );
    for (mode, chunk) in [("off", 1usize), ("on", 32)] {
        let (stats, mut long_ttfts, short_mean) =
            run_prefill_mix(&model, chunk, pbudget, &preqs);
        let long_mean = long_ttfts.iter().sum::<f64>() / long_ttfts.len().max(1) as f64;
        let long_p95 = p95(&mut long_ttfts);
        t5.row(vec![
            mode.into(),
            f2(long_mean * 1e3),
            f2(long_p95 * 1e3),
            f2(short_mean * 1e3),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.prefill_chunks),
            format!("{}", stats.budget_deferrals),
        ]);
        let params = [("workload", "prefill_mix".to_string()), ("chunked", mode.to_string())];
        json.row(&params, "long_mean_ttft_s", long_mean);
        json.row(&params, "long_p95_ttft_s", long_p95);
        json.row(&params, "short_mean_ttft_s", short_mean);
        json.row(&params, "decode_tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "prefill_chunks", stats.prefill_chunks as f64);
        json.row(&params, "prefill_tokens_chunked", stats.prefill_tokens_chunked as f64);
    }
    t5.emit("serving_prefill.md");
    json.emit();
}
