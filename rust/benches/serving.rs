//! **Serving-scaling benchmark**: static (sequence-granular) round batching
//! vs the paged continuous batcher, under the *same* KV byte budget, on a
//! Zipf-ish mixed-length workload (a few long prompts, a long tail of short
//! ones — the shape real traffic has).
//!
//! Shape to hold: the paged scheduler admits strictly more concurrent
//! sequences (its admission unit is a block, not a full `max_seq` cache), so
//! aggregate tok/s rises with the extra fused-batch width while per-request
//! outputs stay bit-identical. The second table sweeps the arena geometry
//! (`--kv-block`): smaller blocks waste less tail capacity but pay more
//! block-table bookkeeping.
//!
//! Emits `BENCH_serving.json` (schema v1) with `tok_per_sec`,
//! `peak_concurrency`, and `evictions` rows per scheduler for the perf
//! trajectory; `scripts/check_bench_json.py --require-paging-gain` enforces
//! the strictly-more-concurrency acceptance gate in CI.

use std::sync::Arc;

use qtip::bench::{f2, samples, BenchJson, Table};
use qtip::coordinator::{
    quantize_model_qtip, GenRequest, ServerConfig, ServerHandle, ServerStats,
};
use qtip::hessian::collect_hessians;
use qtip::model::{KvCache, KvLayout, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;
use qtip::util::Timer;

fn bench_model() -> Arc<Transformer> {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.d_ff = 256;
    cfg.n_layers = 2;
    cfg.max_seq = 128;
    cfg.name = "serving-bench".into();
    let mut model = Transformer::from_store(&WeightStore::random(&cfg, 0xBEEF));
    let seqs = vec![(0..96u16).collect::<Vec<_>>(), (50..146u16).collect::<Vec<_>>()];
    let hs = collect_hessians(&model, &seqs);
    let qcfg = QtipConfig { l: 10, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 7 };
    quantize_model_qtip(&mut model, &hs, &qcfg, &ExecPool::sequential(), |_| {}).unwrap();
    Arc::new(model)
}

/// Zipf-ish mixed-length workload: request r of rank k (cycling 1..=8) gets a
/// prompt of ~`60/k` tokens and a generation budget of ~`48/k` tokens — a few
/// heavy requests, a long tail of light ones.
fn workload(n: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            let rank = (i % 8) + 1;
            GenRequest {
                id: i as u64,
                prompt: "x".repeat((60 / rank).max(1)),
                max_new_tokens: (48 / rank).max(4),
                temperature: 0.0,
                top_k: 1,
                seed: i as u64,
                model: String::new(),
            }
        })
        .collect()
}

/// Run the whole workload through one server; returns (wall secs, stats).
fn run_workload(
    model: &Arc<Transformer>,
    layout: KvLayout,
    kv_block: usize,
    budget: usize,
    reqs: &[GenRequest],
) -> (f64, ServerStats) {
    let server = ServerHandle::spawn(
        model.clone(),
        ServerConfig {
            max_batch: 16,
            kv_budget_bytes: budget,
            kv_layout: layout,
            kv_block,
            ..Default::default()
        },
    );
    let t = Timer::start();
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut total_tokens = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("request served");
        assert!(r.error.is_none(), "bench request rejected: {:?}", r.error);
        total_tokens += r.tokens.len();
    }
    let secs = t.secs();
    let stats = server.shutdown();
    assert_eq!(stats.completed, reqs.len());
    assert!(total_tokens > 0);
    (secs, stats)
}

fn main() {
    let model = bench_model();
    let reps = samples(2);
    let n_requests = 24 * reps.max(1);
    let reqs = workload(n_requests);
    // Budget: two full contiguous caches — tight enough that sequence-
    // granular admission serializes the workload into pairs while the paged
    // arena runs a wide batch from the same bytes.
    let budget = 2 * KvCache::size_bytes_for(&model.cfg);

    let mut json = BenchJson::new("serving");
    let mut t1 = Table::new(
        "Serving: static (contig) vs continuous (paged) batching, same KV budget",
        &["scheduler", "wall s", "tok/s", "peak concurrency", "evictions", "kv high-water B"],
    );
    for (name, layout) in [("contig", KvLayout::Contig), ("paged", KvLayout::Paged)] {
        let (secs, stats) = run_workload(&model, layout, 0, budget, &reqs);
        t1.row(vec![
            name.into(),
            f2(secs),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.peak_active),
            format!("{}", stats.evictions),
            format!("{}", stats.peak_kv_bytes),
        ]);
        let params = [("scheduler", name.to_string())];
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "peak_concurrency", stats.peak_active as f64);
        json.row(&params, "evictions", stats.evictions as f64);
    }
    t1.emit("serving_scheduler.md");

    let mut t2 = Table::new(
        "Paged arena geometry sweep (--kv-block)",
        &["block positions", "blocks", "tok/s", "peak concurrency", "evictions"],
    );
    for block in [8usize, 32, 128] {
        let (_, stats) = run_workload(&model, KvLayout::Paged, block, budget, &reqs);
        t2.row(vec![
            format!("{block}"),
            format!("{}", stats.kv_blocks_total),
            f2(stats.throughput_tok_per_sec()),
            format!("{}", stats.peak_active),
            format!("{}", stats.evictions),
        ]);
        let params = [("kv_block", block.to_string())];
        json.row(&params, "tok_per_sec", stats.throughput_tok_per_sec());
        json.row(&params, "peak_concurrency", stats.peak_active as f64);
    }
    t2.emit("serving_geometry.md");
    json.emit();
}
