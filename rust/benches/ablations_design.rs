//! Design-choice ablations (ours, beyond the paper's tables — DESIGN.md §5):
//!  (a) Viterbi prefix-min optimization vs textbook edge relaxation (same argmin,
//!      measured speedup);
//!  (b) tail-biting strategies: free-end vs Algorithm 4 vs exact;
//!  (c) incoherence processing on/off: proxy loss impact of the RHT.

use qtip::bench::{f3, f4, samples, Table};
use qtip::codes::PureLutCode;
use qtip::quant::{quantize_matrix_qtip, QtipConfig, RhtContext};
use qtip::trellis::{
    quantize_tail_biting, quantize_tail_biting_exact, Trellis, Viterbi, ViterbiWorkspace,
};
use qtip::util::linalg::regularize_spd;
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::stats::mse;
use qtip::util::Timer;

fn main() {
    // (a) Viterbi implementations.
    let mut table = Table::new(
        "Ablation A — Viterbi: prefix-min (ours) vs textbook relaxation",
        &["L", "k", "fast ms/seq", "naive ms/seq", "speedup", "cost match"],
    );
    for (l, k) in [(10u32, 2u32), (12, 2), (12, 4)] {
        let trellis = Trellis::new(l, k, 1);
        let code = PureLutCode::new(l, 1, 1);
        let vit = Viterbi::new(trellis, &code.table);
        let mut rng = Rng::new(5);
        let seq = rng.gauss_vec(256);
        let mut ws = ViterbiWorkspace::new();
        let reps = samples(5);
        let t = Timer::start();
        let mut fast_cost = 0.0;
        for _ in 0..reps {
            fast_cost = vit.quantize(&seq, None, None, &mut ws).1;
        }
        let fast_ms = t.millis() / reps as f64;
        let t = Timer::start();
        let mut naive_cost = 0.0;
        for _ in 0..reps.min(2) {
            naive_cost = vit.quantize_naive(&seq, None, None).1;
        }
        let naive_ms = t.millis() / reps.min(2) as f64;
        table.row(vec![
            l.to_string(),
            k.to_string(),
            f3(fast_ms),
            f3(naive_ms),
            format!("{:.2}x", naive_ms / fast_ms),
            if (fast_cost - naive_cost).abs() < 1e-3 * (1.0 + naive_cost) {
                "yes".into()
            } else {
                format!("NO ({fast_cost} vs {naive_cost})")
            },
        ]);
    }
    table.emit("ablation_viterbi.md");

    // (b) Tail-biting strategies.
    let mut table = Table::new(
        "Ablation B — tail-biting: free-end (needs +L-kV bits) vs Alg.4 vs exact",
        &["k", "free MSE (lower bound)", "Alg.4 MSE", "exact MSE", "Alg.4 overhead %"],
    );
    for k in [1u32, 2, 3] {
        let trellis = Trellis::new(10, k, 1);
        let code = PureLutCode::new(10, 1, 2);
        let vit = Viterbi::new(trellis, &code.table);
        let mut rng = Rng::new(6);
        let mut ws = ViterbiWorkspace::new();
        let n = samples(24);
        let (mut free, mut alg4, mut exact) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let seq = rng.gauss_vec(128);
            let (fs, _) = vit.quantize(&seq, None, None, &mut ws);
            free += mse(&vit.decode(&fs), &seq);
            let a = quantize_tail_biting(&vit, &seq, &mut ws);
            alg4 += mse(&vit.decode(&a.states), &seq);
            let e = quantize_tail_biting_exact(&vit, &seq, &mut ws);
            exact += mse(&vit.decode(&e.states), &seq);
        }
        let (free, alg4, exact) = (free / n as f64, alg4 / n as f64, exact / n as f64);
        table.row(vec![
            k.to_string(),
            f4(free),
            f4(alg4),
            f4(exact),
            format!("{:.2}", 100.0 * (alg4 - exact) / exact),
        ]);
    }
    table.emit("ablation_tailbiting.md");

    // (c) RHT on/off.
    let mut table = Table::new(
        "Ablation C — incoherence processing: relative proxy loss with/without RHT",
        &["weight structure", "with RHT", "without RHT", "RHT wins?"],
    );
    let n = 64;
    let mut rng = Rng::new(7);
    let cfg = QtipConfig { l: 12, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 3 };
    for (label, w) in [
        ("iid gaussian", Matrix::gaussian(n, n, 1.0, &mut rng)),
        ("outlier-heavy", {
            let mut w = Matrix::gaussian(n, n, 0.3, &mut rng);
            for _ in 0..40 {
                let r = rng.below(n);
                let c = rng.below(n);
                *w.at_mut(r, c) = rng.gauss_f32() * 8.0;
            }
            w
        }),
    ] {
        let a = Matrix::gaussian(n, 2 * n, 1.0, &mut rng);
        let mut h = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..2 * n {
                    s += a.at(i, t) * a.at(j, t);
                }
                *h.at_mut(i, j) = s / (2 * n) as f32;
            }
        }
        let h = regularize_spd(&h, 1e-2);
        // With RHT (the normal pipeline).
        let with = quantize_matrix_qtip(&w, &h, &cfg).metrics.relative_proxy;
        // Without RHT: quantize in the original basis — use identity signs by
        // evaluating proxy on a direct LDLQ with the same rounder geometry.
        // (Simplest faithful off-switch: transform with an RHT whose effect we
        // undo by pre-conjugating — here we instead quantize W directly via the
        // same code path on an already-incoherent basis carrier: apply the
        // pipeline to (W, H) where the RHT seed gives identical signs = +1.)
        let without = {
            // Monkey-path: identity RHT == all-+1 signs; emulate by pre-applying
            // the inverse transform so the pipeline's RHT cancels.
            // Same seed as the pipeline's internal context => exact cancellation.
            let ctx = RhtContext::new(w.rows, w.cols, cfg.seed);
            let w_pre = ctx.restore_weight(&w);
            // H side: V S H S V^T cancelled likewise.
            let mut h_pre = h.clone();
            // restore_hessian = apply inverse conjugation on both sides.
            // Reuse transform via two column/row passes of the inverse:
            let mut col = vec![0.0f32; h_pre.rows];
            for c in 0..h_pre.cols {
                for r in 0..h_pre.rows {
                    col[r] = h_pre.at(r, c);
                }
                qtip::util::hadamard::rht_inverse(&mut col, &ctx.sign_cols);
                for r in 0..h_pre.rows {
                    *h_pre.at_mut(r, c) = col[r];
                }
            }
            for r in 0..h_pre.rows {
                qtip::util::hadamard::rht_inverse(h_pre.row_mut(r), &ctx.sign_cols);
            }
            let h_pre = regularize_spd(&h_pre, 1e-2);
            let mut c2 = cfg.clone();
            c2.seed = cfg.seed; // pipeline derives the same ctx internally per seed
            quantize_matrix_qtip(&w_pre, &h_pre, &c2).metrics.relative_proxy
        };
        table.row(vec![
            label.into(),
            f4(with),
            f4(without),
            if with <= without { "yes".into() } else { "no".into() },
        ]);
    }
    table.emit("ablation_rht.md");
}
