//! Shared helpers for the experiment benches (included via `#[path]`).
#![allow(dead_code)]

use std::path::Path;

use qtip::coordinator::{quantize_model_baseline, quantize_model_qtip, QuantizeReport};
use qtip::eval::perplexity;
use qtip::hessian::{collect_hessians, HessianSet};
use qtip::model::{split_corpus, Transformer, WeightStore};
use qtip::quant::{BaselineKind, QtipConfig};
use qtip::util::threadpool::ExecPool;

pub fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load a trained model + (calibration seqs, eval bytes); None if artifacts absent.
pub struct Workload {
    pub name: String,
    pub store: WeightStore,
    pub calib: Vec<Vec<u16>>,
    pub eval: Vec<u8>,
}

impl Workload {
    pub fn load(name: &str, n_calib: usize) -> Option<Workload> {
        let dir = artifacts_dir();
        let store = WeightStore::load(&dir, name).ok()?;
        let holdout = std::fs::read(dir.join("corpus_holdout.bin")).ok()?;
        let (calib_bytes, eval) = split_corpus(&holdout, 0.5);
        let calib = calib_bytes
            .chunks(128)
            .take(n_calib)
            .map(|c| c.iter().map(|&b| b as u16).collect())
            .collect();
        Some(Workload { name: name.into(), store, calib, eval: eval.to_vec() })
    }

    pub fn model(&self) -> Transformer {
        Transformer::from_store(&self.store)
    }

    pub fn hessians(&self, model: &Transformer) -> HessianSet {
        collect_hessians(model, &self.calib)
    }

    /// Quantize with QTIP and return (ppl, report).
    pub fn qtip_ppl(
        &self,
        hs: &HessianSet,
        cfg: &QtipConfig,
        eval_tokens: usize,
    ) -> (f64, QuantizeReport) {
        let mut m = self.model();
        let report =
            quantize_model_qtip(&mut m, hs, cfg, &ExecPool::sequential(), |_| {}).unwrap();
        m.ensure_caches();
        let rep = perplexity(&m, &self.eval, eval_tokens);
        (rep.ppl, report)
    }

    /// Quantize with a baseline rounder and return (ppl, report).
    pub fn baseline_ppl(
        &self,
        hs: &HessianSet,
        kind: &BaselineKind,
        eval_tokens: usize,
    ) -> (f64, QuantizeReport) {
        let mut m = self.model();
        let report =
            quantize_model_baseline(&mut m, hs, kind, 0xBA5E, &ExecPool::sequential()).unwrap();
        let rep = perplexity(&m, &self.eval, eval_tokens);
        (rep.ppl, report)
    }

    pub fn fp32_ppl(&self, eval_tokens: usize) -> f64 {
        perplexity(&self.model(), &self.eval, eval_tokens).ppl
    }
}

pub fn qtip_cfg(code: &str, l: u32, k: u32, v: u32) -> QtipConfig {
    QtipConfig { l, k, v, tx: 16, ty: 16, code: code.into(), seed: 0x5171_50 }
}

/// Skip message when `make artifacts` hasn't run.
pub fn require_workload(name: &str, n_calib: usize) -> Option<Workload> {
    match Workload::load(name, n_calib) {
        Some(w) => Some(w),
        None => {
            println!("SKIPPED: trained model '{name}' not found — run `make artifacts` first");
            None
        }
    }
}
