//! **Figure 1 reproduction (shape)**: quality vs total compressed size across
//! model scales and bitrates — "2 bit models scale better than 4 bit models".
//!
//! We sweep {micro, nano} × {2, 3, 4} bits and emit the (bytes, ppl) frontier.
//! Shape to hold: at matched storage, the larger-model/lower-bit point is at
//! least as good as the smaller-model/higher-bit point (the 2-bit frontier
//! dominates as scale grows).

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};

fn main() {
    let eval_tokens = 256 * samples(4);
    let mut table = Table::new(
        "Figure 1 — ppl vs compressed decoder size (QTIP 3INST, L=12)",
        &["model", "bits", "decoder KiB", "ppl"],
    );
    let mut points: Vec<(String, u32, f64, f64)> = Vec::new();

    for name in ["micro", "nano"] {
        let Some(w) = require_workload(name, 16) else { continue };
        let model = w.model();
        let hs = w.hessians(&model);
        let fp32 = w.fp32_ppl(eval_tokens);
        println!("{name}: fp32 ppl {fp32:.3}");
        for k in [2u32, 3, 4] {
            let (ppl, rep) = w.qtip_ppl(&hs, &qtip_cfg("3inst", 12, k, 1), eval_tokens);
            let kib = rep.bytes_after as f64 / 1024.0;
            table.row(vec![name.into(), k.to_string(), f3(kib), f3(ppl)]);
            points.push((name.into(), k, kib, ppl));
            println!("  k={k}: {kib:.0} KiB -> ppl {ppl:.3}");
        }
    }
    table.emit("fig1_scaling.md");

    // The Figure-1 comparison: nano@2bit vs micro@4bit (similar storage class).
    let nano2 = points.iter().find(|p| p.0 == "nano" && p.1 == 2);
    let micro4 = points.iter().find(|p| p.0 == "micro" && p.1 == 4);
    if let (Some(n2), Some(m4)) = (nano2, micro4) {
        println!(
            "\nFigure-1 check: nano@2bit ({:.0} KiB, ppl {:.3}) vs micro@4bit ({:.0} KiB, ppl {:.3}) — larger-model-fewer-bits {}",
            n2.2,
            n2.3,
            m4.2,
            m4.3,
            if n2.3 < m4.3 { "WINS (matches paper)" } else { "does not win at this scale" }
        );
    }
    // CSV for plotting.
    let mut csv = String::from("model,bits,kib,ppl\n");
    for (m, k, kib, ppl) in &points {
        csv.push_str(&format!("{m},{k},{kib:.1},{ppl:.4}\n"));
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig1_scaling.csv", csv).ok();
}
