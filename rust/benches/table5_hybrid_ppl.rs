//! **Table 5 reproduction (shape)**: the hybrid lookup-computed code vs VQ
//! methods across model sizes and bitrates (micro + nano as the Llama-1/2 family
//! substitute).
//!
//! Shape to hold: QTIP-HYB ≤ E8P-VQ ≤ scalar at every (model, bits); everything
//! approaches fp32 as bits increase.

#[path = "common.rs"]
mod common;

use common::{qtip_cfg, require_workload};
use qtip::bench::{f3, samples, Table};
use qtip::quant::BaselineKind;

fn main() {
    let eval_tokens = 256 * samples(6);
    let mut table = Table::new(
        "Table 5 — QTIP (HYB, V=2 Q=9) vs VQ baselines: held-out ppl",
        &["model", "fp32", "bits", "QTIP HYB", "QTIP 3INST", "E8P-RVQ", "Scalar"],
    );

    for name in ["micro", "nano"] {
        let Some(w) = require_workload(name, 16) else { continue };
        let model = w.model();
        let hs = w.hessians(&model);
        let fp32 = w.fp32_ppl(eval_tokens);
        for k in [4u32, 3, 2] {
            let mut hyb_cfg = qtip_cfg("hyb", 12, k, 2);
            hyb_cfg.seed = 0xB0B;
            let (ph, _) = w.qtip_ppl(&hs, &hyb_cfg, eval_tokens);
            let (p3, _) = w.qtip_ppl(&hs, &qtip_cfg("3inst", 12, k, 1), eval_tokens);
            let (pv, _) = w.baseline_ppl(
                &hs,
                &BaselineKind::E8Rvq { k, entries: 1 << 16 },
                eval_tokens,
            );
            let (ps, _) = w.baseline_ppl(&hs, &BaselineKind::Scalar { k }, eval_tokens);
            table.row(vec![
                name.into(),
                f3(fp32),
                k.to_string(),
                f3(ph),
                f3(p3),
                f3(pv),
                f3(ps),
            ]);
            println!("{name} k={k}: hyb {ph:.3} 3inst {p3:.3} e8p {pv:.3} scalar {ps:.3} (fp32 {fp32:.3})");
        }
    }
    table.emit("table5_hybrid_ppl.md");
}
