#!/usr/bin/env python3
"""End-to-end smoke for the HTTP/SSE front door and multi-model routing.

Usage: http_smoke.py <qtip-binary> <artifact> [<artifact2>]

Phase 1 (always): serve <artifact> with both frontends bound to ephemeral
ports and assert
  * GET /health and GET /v1/models answer, and the models list has a default;
  * POST /v1/generate (non-stream) returns 200 with a tokens array;
  * the same request over the raw newline-JSON TCP frontend returns the
    *identical* token ids (the two front doors share one batcher — token
    parity is the acceptance criterion, not mere liveness);
  * POST /v1/generate with "stream": true returns text/event-stream whose
    per-token events reassemble to exactly the unary response;
  * an unknown route 404s with a structured JSON error.

Phase 2 (with <artifact2>): serve both artifacts as named lanes and assert
  * /v1/models lists both lanes;
  * "model": <lane> routes to each lane (200 + tokens);
  * an unknown "model" gets a structured 404 whose error names the lanes;
  * the default (no "model") equals an explicit route to the first lane.

Everything is stdlib-only; the server is shut down with SIGINT and must exit
cleanly (the Ctrl-C drain path is part of the smoke).
"""

import json
import re
import signal
import socket
import subprocess
import sys
import time
import urllib.error
import urllib.request

TIMEOUT = 60  # seconds for any single wait


def fail(msg, proc=None):
    print(f"http_smoke: FAIL: {msg}", file=sys.stderr)
    if proc is not None:
        proc.kill()
        out, _ = proc.communicate(timeout=10)
        print("---- server output ----", file=sys.stderr)
        print(out, file=sys.stderr)
    sys.exit(1)


def start_server(qtip, artifacts):
    cmd = [qtip, "serve"]
    for a in artifacts:
        cmd += ["--artifact", a]
    cmd += ["--tcp", "127.0.0.1:0", "--http", "127.0.0.1:0", "--threads", "2"]
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    # The serve banner prints one "listening on" line per frontend with the
    # resolved (ephemeral) port; models line follows both.
    tcp_addr = http_addr = None
    deadline = time.monotonic() + TIMEOUT
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            fail("server exited before binding its frontends", proc)
        m = re.search(r"listening on tcp://(\S+)", line)
        if m:
            tcp_addr = m.group(1)
        m = re.search(r"listening on http://(\S+) ", line)
        if m:
            http_addr = m.group(1)
        if "models:" in line and tcp_addr and http_addr:
            return proc, tcp_addr, http_addr
    fail("timed out waiting for the serve banner", proc)


def stop_server(proc):
    proc.send_signal(signal.SIGINT)
    try:
        out, _ = proc.communicate(timeout=TIMEOUT)
    except subprocess.TimeoutExpired:
        fail("server did not drain and exit after SIGINT", proc)
    if proc.returncode != 0:
        print("---- server output ----", file=sys.stderr)
        print(out, file=sys.stderr)
        fail(f"server exited with status {proc.returncode}")
    return out


def http_req(http_addr, method, path, body=None):
    """Returns (status, parsed-JSON body or raw text, content-type)."""
    url = f"http://{http_addr}{path}"
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(url, data=data, method=method)
    if data is not None:
        req.add_header("Content-Type", "application/json")
    try:
        with urllib.request.urlopen(req, timeout=TIMEOUT) as resp:
            status, raw = resp.status, resp.read()
            ctype = resp.headers.get("Content-Type", "")
    except urllib.error.HTTPError as e:
        status, raw = e.code, e.read()
        ctype = e.headers.get("Content-Type", "")
    text = raw.decode("utf-8", "replace")
    if ctype.startswith("application/json"):
        return status, json.loads(text), ctype
    return status, text, ctype


def sse_events(http_addr, body):
    """POST a streaming generate and return the parsed `data:` events."""
    status, text, ctype = http_req(
        http_addr, "POST", "/v1/generate", {**body, "stream": True}
    )
    if status != 200:
        fail(f"SSE request got status {status}: {text}")
    if not ctype.startswith("text/event-stream"):
        fail(f"SSE response Content-Type is {ctype!r}")
    events = []
    for block in text.split("\n\n"):
        for line in block.splitlines():
            if line.startswith("data: "):
                events.append(json.loads(line[len("data: "):]))
    if not events:
        fail("SSE stream carried no events")
    return events


def tcp_generate(tcp_addr, body):
    host, port = tcp_addr.rsplit(":", 1)
    with socket.create_connection((host, int(port)), timeout=TIMEOUT) as s:
        s.sendall((json.dumps(body) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(4096)
            if not chunk:
                break
            buf += chunk
    return json.loads(buf.decode())


GEN = {"prompt": "the quick brown fox", "max_new_tokens": 12, "temperature": 0.7,
       "top_k": 40, "seed": 1234}


def phase_single(qtip, artifact):
    proc, tcp_addr, http_addr = start_server(qtip, [artifact])
    try:
        status, health, _ = http_req(http_addr, "GET", "/health")
        if status != 200 or health.get("status") != "ok":
            fail(f"/health: {status} {health}", proc)
        status, models, _ = http_req(http_addr, "GET", "/v1/models")
        if status != 200 or not models.get("models") or not models.get("default"):
            fail(f"/v1/models: {status} {models}", proc)

        # The terminal response object carries `text` (the generated string)
        # and `tokens` (a count) — `text` is the parity-checked payload.
        status, unary, _ = http_req(http_addr, "POST", "/v1/generate", GEN)
        if status != 200 or unary.get("error") or not unary.get("text"):
            fail(f"unary generate: {status} {unary}", proc)

        over_tcp = tcp_generate(tcp_addr, GEN)
        if over_tcp.get("text") != unary["text"]:
            fail(
                f"HTTP and TCP front doors disagree: "
                f"{unary['text']!r} vs {over_tcp.get('text')!r}",
                proc,
            )

        events = sse_events(http_addr, GEN)
        terminal = events[-1]
        if not terminal.get("done"):
            fail(f"last SSE event is not terminal: {terminal}", proc)
        if terminal.get("error"):
            fail(f"SSE stream ended in error: {terminal}", proc)
        streamed = "".join(e.get("text", "") for e in events[:-1])
        if streamed != unary["text"] or terminal.get("text") != unary["text"]:
            fail(
                f"SSE text diverges from unary: {streamed!r} / "
                f"{terminal.get('text')!r} vs {unary['text']!r}",
                proc,
            )
        if len(events) - 1 != unary["tokens"]:
            fail(
                f"SSE carried {len(events) - 1} token events for a "
                f"{unary['tokens']}-token response",
                proc,
            )

        status, err, _ = http_req(http_addr, "GET", "/v1/nope")
        if status != 404 or "error" not in err:
            fail(f"unknown route: {status} {err}", proc)
    except Exception:
        proc.kill()
        raise
    stop_server(proc)
    print(f"http_smoke: single-model phase ok ({unary['tokens']} tokens, "
          f"HTTP == TCP == SSE)")


def phase_multi(qtip, artifacts):
    proc, _tcp_addr, http_addr = start_server(qtip, artifacts)
    try:
        status, models, _ = http_req(http_addr, "GET", "/v1/models")
        if status != 200 or sorted(models.get("models", [])) != sorted(artifacts):
            fail(f"/v1/models with two lanes: {status} {models}", proc)

        per_lane = {}
        for lane in artifacts:
            status, resp, _ = http_req(
                http_addr, "POST", "/v1/generate", {**GEN, "model": lane}
            )
            if status != 200 or resp.get("error") or not resp.get("text"):
                fail(f"lane '{lane}' generate: {status} {resp}", proc)
            per_lane[lane] = resp["text"]

        status, resp, _ = http_req(http_addr, "POST", "/v1/generate", GEN)
        if status != 200 or resp.get("text") != per_lane[artifacts[0]]:
            fail(f"default route != first lane: {status} {resp}", proc)

        status, rej, _ = http_req(
            http_addr, "POST", "/v1/generate", {**GEN, "model": "no-such-lane"}
        )
        err = rej.get("error") or ""
        if status != 404 or "unknown model" not in err:
            fail(f"unknown model must 404 with a structured error: {status} {rej}", proc)
        for lane in artifacts:
            if lane not in err:
                fail(f"rejection error must name lane '{lane}': {err}", proc)
    except Exception:
        proc.kill()
        raise
    stop_server(proc)
    print(f"http_smoke: multi-model phase ok (lanes {artifacts}, unknown lane 404s)")


if __name__ == "__main__":
    if len(sys.argv) < 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    qtip_bin = sys.argv[1]
    arts = sys.argv[2:]
    phase_single(qtip_bin, arts[0])
    if len(arts) > 1:
        phase_multi(qtip_bin, arts[:2])
    print("http_smoke: all phases passed")
