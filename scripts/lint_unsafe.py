#!/usr/bin/env python3
"""Repo soundness lint: SAFETY comments and Ordering::Relaxed policing.

Two rules, enforced over every tracked .rs file under rust/ (CI runs this in
the lint job; run locally with `python3 scripts/lint_unsafe.py`):

1. **Every `unsafe` site needs a real SAFETY comment.**
   - `unsafe { ... }` blocks and `unsafe impl` items must have a line whose
     comment starts with `SAFETY:` within the preceding context window (the
     same convention clippy's `undocumented_unsafe_blocks` checks at compile
     time — this lint is the textual backstop that also covers cfg'd-out code
     and runs without a Rust toolchain).
   - `unsafe fn` declarations must carry a `# Safety` doc section (or a
     `SAFETY:` comment) explaining the caller contract.
   - `unsafe` in *type* position (`fn(...)` pointer types) is not a site.

2. **`Ordering::Relaxed` is allowlist-only.** Every line using Relaxed must
   match an entry in scripts/relaxed_allowlist.txt (format:
   `<repo-relative path> | <line substring>`). The allowlist carries a written
   justification per entry; a new Relaxed use fails this lint until it is
   justified there or upgraded to an acquire/release ordering.

Exit status 0 iff no violations. No third-party dependencies.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RUST_ROOTS = [REPO / "rust"]
ALLOWLIST = REPO / "scripts" / "relaxed_allowlist.txt"

# How far above an `unsafe` site a SAFETY comment may sit. Small on purpose:
# a comment ten lines away is not documenting *this* block.
SAFETY_WINDOW = 6
# How far above an `unsafe fn` a doc comment block may declare `# Safety`.
DOC_WINDOW = 30

SAFETY_RE = re.compile(r"(//|/\*)[/!*\s]*SAFETY:")
DOC_SAFETY_RE = re.compile(r"(///|//!).*#\s*Safety")
RELAXED_RE = re.compile(r"Ordering::Relaxed")
# `unsafe` in type position: `: unsafe fn(`, `(unsafe fn(`, `-> unsafe fn(`.
TYPE_POS_RE = re.compile(r"(:|\(|->)\s*unsafe\s+fn\s*\(")
STRING_RE = re.compile(r'"(?:[^"\\]|\\.)*"')


def rs_files() -> list[Path]:
    out: list[Path] = []
    for root in RUST_ROOTS:
        for p in sorted(root.rglob("*.rs")):
            if "target" in p.parts:
                continue
            out.append(p)
    return out


def strip_noise(line: str) -> str:
    """Remove string literals and line comments so tokens inside them do not
    register as code. (Block comments spanning lines are handled by the
    caller's in_block_comment state.)"""
    line = STRING_RE.sub('""', line)
    cut = line.find("//")
    if cut != -1:
        line = line[:cut]
    return line


def classify_unsafe(code: str) -> str | None:
    """Return the kind of unsafe site on this code line, if any."""
    if TYPE_POS_RE.search(code):
        code = TYPE_POS_RE.sub("", code)
    if not re.search(r"\bunsafe\b", code):
        return None
    if re.search(r"\bunsafe\s+impl\b", code):
        return "impl"
    if re.search(r"\bunsafe\s+(?:extern\s+\S+\s+)?fn\b", code):
        return "fn"
    return "block"


def has_safety_above(lines: list[str], idx: int, window: int, doc_ok: bool) -> bool:
    lo = max(0, idx - window)
    for j in range(idx, lo - 1, -1):
        line = lines[j]
        if SAFETY_RE.search(line):
            return True
        if doc_ok and DOC_SAFETY_RE.search(line):
            return True
    return False


def load_allowlist() -> list[tuple[str, str]]:
    entries: list[tuple[str, str]] = []
    if not ALLOWLIST.exists():
        return entries
    for raw in ALLOWLIST.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "|" not in line:
            print(f"relaxed_allowlist.txt: malformed entry (need 'path | substring'): {line}")
            sys.exit(2)
        path, sub = (part.strip() for part in line.split("|", 1))
        entries.append((path, sub))
    return entries


def main() -> int:
    violations: list[str] = []
    allow = load_allowlist()
    used = [False] * len(allow)

    for path in rs_files():
        rel = path.relative_to(REPO).as_posix()
        lines = path.read_text().splitlines()
        in_block_comment = False
        for i, raw in enumerate(lines):
            line = raw
            if in_block_comment:
                end = line.find("*/")
                if end == -1:
                    continue
                line = line[end + 2 :]
                in_block_comment = False
            # Strip (possibly several) block comments opening on this line.
            while True:
                start = line.find("/*")
                if start == -1:
                    break
                end = line.find("*/", start + 2)
                if end == -1:
                    line = line[:start]
                    in_block_comment = True
                    break
                line = line[:start] + line[end + 2 :]
            code = strip_noise(line)

            kind = classify_unsafe(code)
            if kind == "impl" or kind == "block":
                if not has_safety_above(lines, i, SAFETY_WINDOW, doc_ok=False):
                    violations.append(
                        f"{rel}:{i + 1}: unsafe {kind} without a '// SAFETY:' comment"
                    )
            elif kind == "fn":
                if not has_safety_above(lines, i, DOC_WINDOW, doc_ok=True):
                    violations.append(
                        f"{rel}:{i + 1}: unsafe fn without a '# Safety' doc section"
                    )

            if RELAXED_RE.search(code):
                hit = False
                for k, (apath, sub) in enumerate(allow):
                    if apath == rel and sub in raw:
                        used[k] = True
                        hit = True
                        break
                if not hit:
                    violations.append(
                        f"{rel}:{i + 1}: Ordering::Relaxed not in scripts/relaxed_allowlist.txt "
                        f"(justify it there or use an acquire/release ordering)"
                    )

    for (apath, sub), u in zip(allow, used):
        if not u:
            print(f"warning: stale allowlist entry never matched: {apath} | {sub}")

    if violations:
        print(f"{len(violations)} soundness-lint violation(s):")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"lint_unsafe: OK ({len(rs_files())} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
