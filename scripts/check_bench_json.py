#!/usr/bin/env python3
"""Schema check for the perf-trajectory files (BENCH_*.json at the repo root).

Usage: check_bench_json.py [--min-lanes-speedup X] [--require-paging-gain]
                           [--require-prefix-gain] [--require-shed-sanity]
                           [--require-prefill-gain]
                           BENCH_microbench.json [...]

Pins the same contract as `bench::BenchJson` (rust/src/bench.rs) and its
`bench_json_schema_roundtrips` unit test: top-level bench / schema_version /
git_rev / config / rows, with rows of {params: {str: str}, metric: str,
value: number}. Exits non-zero (with a pointed message) on any violation so
CI catches schema drift before a downstream comparison tool does.

With `--min-lanes-speedup X`, additionally enforces the lane-kernel
acceptance gate on any file carrying `lanes_speedup` rows: the measured
speedup for the pure-computed codes (1mad, 3inst) must be >= X.

With `--require-paging-gain`, enforces the paged-KV acceptance gate on any
file carrying `peak_concurrency` rows keyed by a `scheduler` param (the
serving bench): the paged scheduler's peak concurrency must be *strictly
greater* than the contiguous (sequence-granular) scheduler's under the same
KV budget.

With `--require-prefix-gain`, enforces the prefix-sharing acceptance gate on
the Zipf-shared-prefix serving rows (params carrying `workload=zipf_prefix`
and `prefix=on|off`): under the same tight KV budget, prefix-on must admit
*strictly more* peak concurrency AND deliver *strictly lower* mean TTFT than
prefix-off, and must actually report prefix-index hits.

With `--require-shed-sanity`, enforces the overload-shedding acceptance gate
on the serving rows keyed by `workload=nominal|overload`: both workloads
must be present, the overload burst must actually shed (`shed_queue_full`
> 0) while the nominal run sheds nothing, and the mean TTFT of the requests
the overload run *admitted* must stay within 2x of the uncontended nominal
mean — shedding exists to protect latency, so an overload TTFT blowup means
the bound is not doing its job.

With `--require-prefill-gain`, enforces the chunked-prefill acceptance gate
on the long/short-mix serving rows (params carrying `workload=prefill_mix`
and `chunked=on|off`): at the same KV budget, the chunked run must deliver
*strictly lower* long-prompt mean AND p95 TTFT than the token-at-a-time run,
keep decode throughput within 10% (>= 0.9x), and actually report GEMM
prefill chunks — decoding each weight tile once per chunk of prompt
positions must shorten time to first token without costing steady-state
decode.
"""

import json
import sys

SCHEMA_VERSION = 1
# The quant-method registry (mirrors rust/src/quant/registry.rs): benches key
# per-method rows by registry name, one row set per registered method. A
# lanes_speedup row with a code outside this list is schema drift.
REGISTRY_CODES = ("1mad", "3inst", "hyb", "lut", "vptq")
# Codes whose lanes_speedup rows the --min-lanes-speedup gate applies to:
# the pure-computed codes vectorize fully; the table-driven methods (HYB,
# LUT, VPTQ) are gather-bound and only schema-checked.
GATED_CODES = ("1mad", "3inst")


def fail(msg: str) -> None:
    print(f"check_bench_json: {msg}", file=sys.stderr)
    sys.exit(1)


def check_speedup_gate(path: str, doc: dict, min_speedup: float) -> None:
    rows = [r for r in doc["rows"] if r["metric"] == "lanes_speedup"]
    if not rows:
        return
    gated = 0
    ungated = []
    for row in rows:
        code = row["params"].get("code", "?")
        if code not in REGISTRY_CODES:
            fail(
                f"{path}: lanes_speedup row for unknown code '{code}' — not a "
                f"registry name {REGISTRY_CODES}; update the registry mirror if "
                f"a method was added"
            )
        if code not in GATED_CODES:
            ungated.append(code)
            continue
        gated += 1
        if row["value"] < min_speedup:
            fail(
                f"{path}: lanes_speedup for '{code}' is {row['value']:.2f}, "
                f"below the {min_speedup:.2f}x acceptance gate"
            )
    if gated != len(GATED_CODES):
        fail(f"{path}: expected lanes_speedup rows for {GATED_CODES}, found {gated}")
    print(
        f"{path}: lanes_speedup gate ok (>= {min_speedup:.2f}x for {GATED_CODES}; "
        f"schema-checked only: {sorted(set(ungated))})"
    )


def check_paging_gate(path: str, doc: dict) -> None:
    rows = [
        r
        for r in doc["rows"]
        if r["metric"] == "peak_concurrency" and "scheduler" in r["params"]
    ]
    if not rows:
        # Unlike --min-lanes-speedup (applied across a file list where some
        # files legitimately lack the metric), this gate is pointed at the one
        # file that must carry the rows — an empty match means the serving
        # bench stopped emitting the acceptance metric, which must fail loudly
        # rather than silently disable the gate.
        fail(
            f"{path}: --require-paging-gain found no peak_concurrency rows keyed by "
            f"'scheduler' — the serving bench no longer emits the acceptance metric"
        )
    by_sched = {r["params"]["scheduler"]: r["value"] for r in rows}
    for sched in ("contig", "paged"):
        if sched not in by_sched:
            fail(f"{path}: paging gate needs a peak_concurrency row for '{sched}'")
    if not by_sched["paged"] > by_sched["contig"]:
        fail(
            f"{path}: paged peak_concurrency {by_sched['paged']:.0f} is not strictly "
            f"greater than contig {by_sched['contig']:.0f} — the paged arena must admit "
            f"more sequences than sequence-granular admission under the same budget"
        )
    print(
        f"{path}: paging gate ok (paged {by_sched['paged']:.0f} > "
        f"contig {by_sched['contig']:.0f} peak concurrency)"
    )


def check_prefix_gate(path: str, doc: dict) -> None:
    zrows = [r for r in doc["rows"] if r["params"].get("workload") == "zipf_prefix"]
    if not zrows:
        # Same loud-failure stance as --require-paging-gain: this gate is
        # pointed at the one file that must carry the rows, so an empty match
        # means the bench stopped emitting them.
        fail(
            f"{path}: --require-prefix-gain found no workload=zipf_prefix rows — "
            f"the serving bench no longer emits the prefix-sharing acceptance metrics"
        )
    vals: dict = {}
    for r in zrows:
        mode = r["params"].get("prefix")
        if mode not in ("on", "off"):
            fail(f"{path}: zipf_prefix row with bad prefix param {mode!r}")
        vals.setdefault(mode, {})[r["metric"]] = r["value"]
    for mode in ("on", "off"):
        for metric in ("peak_concurrency", "mean_ttft_s", "prefix_hits"):
            if metric not in vals.get(mode, {}):
                fail(f"{path}: prefix gate needs a {metric} row for prefix={mode}")
    on, off = vals["on"], vals["off"]
    if not on["prefix_hits"] > 0:
        fail(
            f"{path}: prefix-on run reported zero prefix_hits — the index never "
            f"aliased a block, so the comparison is vacuous"
        )
    if not on["peak_concurrency"] > off["peak_concurrency"]:
        fail(
            f"{path}: prefix-on peak_concurrency {on['peak_concurrency']:.0f} is not "
            f"strictly greater than prefix-off {off['peak_concurrency']:.0f} — aliasing "
            f"the shared prefix must admit more sequences under the same budget"
        )
    if not on["mean_ttft_s"] < off["mean_ttft_s"]:
        fail(
            f"{path}: prefix-on mean TTFT {on['mean_ttft_s'] * 1e3:.2f} ms is not "
            f"strictly lower than prefix-off {off['mean_ttft_s'] * 1e3:.2f} ms — "
            f"skipping aliased prefill must shorten time to first token"
        )
    print(
        f"{path}: prefix gate ok (concurrency {on['peak_concurrency']:.0f} > "
        f"{off['peak_concurrency']:.0f}, mean TTFT {on['mean_ttft_s'] * 1e3:.2f} < "
        f"{off['mean_ttft_s'] * 1e3:.2f} ms, {on['prefix_hits']:.0f} hits)"
    )


def check_shed_gate(path: str, doc: dict) -> None:
    srows = [
        r for r in doc["rows"] if r["params"].get("workload") in ("nominal", "overload")
    ]
    if not srows:
        # Same loud-failure stance as the other pointed gates: an empty match
        # means the serving bench stopped emitting the overload rows.
        fail(
            f"{path}: --require-shed-sanity found no workload=nominal|overload rows — "
            f"the serving bench no longer emits the overload-shedding metrics"
        )
    vals: dict = {}
    for r in srows:
        vals.setdefault(r["params"]["workload"], {})[r["metric"]] = r["value"]
    for wl in ("nominal", "overload"):
        for metric in ("shed_queue_full", "mean_ttft_s", "completed"):
            if metric not in vals.get(wl, {}):
                fail(f"{path}: shed gate needs a {metric} row for workload={wl}")
    nominal, overload = vals["nominal"], vals["overload"]
    if not overload["shed_queue_full"] > 0:
        fail(
            f"{path}: overload run shed nothing — a burst past the bounded queue must "
            f"produce queue_full rejections, or the admission bound is not engaged"
        )
    if nominal["shed_queue_full"] != 0:
        fail(
            f"{path}: nominal run shed {nominal['shed_queue_full']:.0f} request(s) — "
            f"an in-capacity workload must never be load-shed"
        )
    if not overload["completed"] > 0:
        fail(f"{path}: overload run admitted nothing — the TTFT comparison is vacuous")
    if not overload["mean_ttft_s"] <= 2.0 * nominal["mean_ttft_s"]:
        fail(
            f"{path}: overload admitted-request mean TTFT {overload['mean_ttft_s'] * 1e3:.2f} ms "
            f"exceeds 2x the nominal {nominal['mean_ttft_s'] * 1e3:.2f} ms — shedding must "
            f"protect the latency of the requests it admits"
        )
    print(
        f"{path}: shed gate ok (overload shed {overload['shed_queue_full']:.0f}, "
        f"nominal shed 0, admitted TTFT {overload['mean_ttft_s'] * 1e3:.2f} ms <= "
        f"2x nominal {nominal['mean_ttft_s'] * 1e3:.2f} ms)"
    )


def check_prefill_gate(path: str, doc: dict) -> None:
    prows = [r for r in doc["rows"] if r["params"].get("workload") == "prefill_mix"]
    if not prows:
        # Same loud-failure stance as the other pointed gates: an empty match
        # means the serving bench stopped emitting the prefill-mix rows.
        fail(
            f"{path}: --require-prefill-gain found no workload=prefill_mix rows — "
            f"the serving bench no longer emits the chunked-prefill acceptance metrics"
        )
    vals: dict = {}
    for r in prows:
        mode = r["params"].get("chunked")
        if mode not in ("on", "off"):
            fail(f"{path}: prefill_mix row with bad chunked param {mode!r}")
        vals.setdefault(mode, {})[r["metric"]] = r["value"]
    for mode in ("on", "off"):
        for metric in (
            "long_mean_ttft_s",
            "long_p95_ttft_s",
            "decode_tok_per_sec",
            "prefill_chunks",
        ):
            if metric not in vals.get(mode, {}):
                fail(f"{path}: prefill gate needs a {metric} row for chunked={mode}")
    on, off = vals["on"], vals["off"]
    if not on["prefill_chunks"] > 0:
        fail(
            f"{path}: chunked-on run reported zero prefill_chunks — prompts never went "
            f"through the GEMM path, so the comparison is vacuous"
        )
    if off["prefill_chunks"] != 0:
        fail(
            f"{path}: chunked-off run reported {off['prefill_chunks']:.0f} prefill "
            f"chunks — the token-at-a-time baseline must not chunk"
        )
    if not on["long_mean_ttft_s"] < off["long_mean_ttft_s"]:
        fail(
            f"{path}: chunked long-prompt mean TTFT {on['long_mean_ttft_s'] * 1e3:.2f} ms "
            f"is not strictly lower than token-at-a-time "
            f"{off['long_mean_ttft_s'] * 1e3:.2f} ms — GEMM prefill must shorten time "
            f"to first token on long prompts"
        )
    if not on["long_p95_ttft_s"] < off["long_p95_ttft_s"]:
        fail(
            f"{path}: chunked long-prompt p95 TTFT {on['long_p95_ttft_s'] * 1e3:.2f} ms "
            f"is not strictly lower than token-at-a-time "
            f"{off['long_p95_ttft_s'] * 1e3:.2f} ms — the tail must improve too"
        )
    if not on["decode_tok_per_sec"] >= 0.9 * off["decode_tok_per_sec"]:
        fail(
            f"{path}: chunked decode throughput {on['decode_tok_per_sec']:.1f} tok/s "
            f"fell below 90% of token-at-a-time {off['decode_tok_per_sec']:.1f} tok/s — "
            f"prefill chunking must not cost steady-state decode"
        )
    print(
        f"{path}: prefill gate ok (long mean TTFT {on['long_mean_ttft_s'] * 1e3:.2f} < "
        f"{off['long_mean_ttft_s'] * 1e3:.2f} ms, p95 {on['long_p95_ttft_s'] * 1e3:.2f} < "
        f"{off['long_p95_ttft_s'] * 1e3:.2f} ms, decode {on['decode_tok_per_sec']:.1f} >= "
        f"0.9x {off['decode_tok_per_sec']:.1f} tok/s, {on['prefill_chunks']:.0f} chunks)"
    )


def check(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: unreadable or invalid JSON: {e}")

    for key, typ in [
        ("bench", str),
        ("git_rev", str),
        ("schema_version", (int, float)),
        ("config", dict),
        ("rows", list),
    ]:
        if key not in doc:
            fail(f"{path}: missing top-level key '{key}'")
        if not isinstance(doc[key], typ):
            fail(f"{path}: '{key}' has type {type(doc[key]).__name__}")
    if int(doc["schema_version"]) != SCHEMA_VERSION:
        fail(f"{path}: schema_version {doc['schema_version']} != {SCHEMA_VERSION}")
    if not doc["rows"]:
        fail(f"{path}: no measurement rows")
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict):
            fail(f"{path} row {i}: not an object")
        params = row.get("params")
        if not isinstance(params, dict) or not all(
            isinstance(k, str) and isinstance(v, str) for k, v in params.items()
        ):
            fail(f"{path} row {i}: params must be a string->string object")
        if not isinstance(row.get("metric"), str) or not row["metric"]:
            fail(f"{path} row {i}: metric must be a non-empty string")
        if not isinstance(row.get("value"), (int, float)) or isinstance(row["value"], bool):
            fail(f"{path} row {i}: value must be a number")
    print(
        f"{path}: ok — bench '{doc['bench']}', rev {doc['git_rev']}, "
        f"{len(doc['rows'])} rows"
    )
    return doc


if __name__ == "__main__":
    args = sys.argv[1:]
    min_speedup = None
    require_paging_gain = False
    require_prefix_gain = False
    require_shed_sanity = False
    require_prefill_gain = False
    while args and args[0].startswith("--"):
        if args[0] == "--min-lanes-speedup":
            if len(args) < 2:
                fail("--min-lanes-speedup needs a value")
            min_speedup = float(args[1])
            args = args[2:]
        elif args[0] == "--require-paging-gain":
            require_paging_gain = True
            args = args[1:]
        elif args[0] == "--require-prefix-gain":
            require_prefix_gain = True
            args = args[1:]
        elif args[0] == "--require-shed-sanity":
            require_shed_sanity = True
            args = args[1:]
        elif args[0] == "--require-prefill-gain":
            require_prefill_gain = True
            args = args[1:]
        else:
            fail(f"unknown flag {args[0]}")
    if not args:
        fail(
            "usage: check_bench_json.py [--min-lanes-speedup X] [--require-paging-gain] "
            "[--require-prefix-gain] [--require-shed-sanity] [--require-prefill-gain] "
            "BENCH_<name>.json [...]"
        )
    for p in args:
        document = check(p)
        if min_speedup is not None:
            check_speedup_gate(p, document, min_speedup)
        if require_paging_gain:
            check_paging_gate(p, document)
        if require_prefix_gain:
            check_prefix_gate(p, document)
        if require_shed_sanity:
            check_shed_gate(p, document)
        if require_prefill_gain:
            check_prefill_gate(p, document)
